//! The length-prefixed binary wire protocol spoken between
//! [`super::SketchClient`] and [`super::SketchServer`].
//!
//! # Frame layout
//!
//! Every message — request or response — is one frame (all integers
//! little-endian):
//!
//! | offset | size | field                                          |
//! |--------|------|------------------------------------------------|
//! | 0      | 2    | magic `b"HL"` ([`MAGIC`])                      |
//! | 2      | 1    | protocol version ([`PROTO_VERSION`], currently 1) |
//! | 3      | 1    | opcode (see [`opcodes`])                       |
//! | 4      | 4    | payload length, u32 LE (≤ [`MAX_PAYLOAD`])     |
//! | 8      | n    | payload                                        |
//!
//! # Request payloads
//!
//! | opcode            | payload                                               |
//! |-------------------|-------------------------------------------------------|
//! | `PING`            | empty                                                 |
//! | `INSERT_BATCH`    | key u64 · count u32 · count × word u32                |
//! | `ESTIMATE`        | key u64                                               |
//! | `GLOBAL_ESTIMATE` | empty                                                 |
//! | `MERGE_SKETCH`    | key u64 · len u32 · len × sketch wire-format-v2 bytes |
//! | `STATS`           | empty                                                 |
//! | `EVICT`           | policy u8 (0=key, 1=idle, 2=budget, 3=idle_wall) · argument u64 |
//! | `SNAPSHOT`        | empty                                                 |
//! | `SUBSCRIBE`       | epoch u64 · cursor u64 · wire u8 (epoch 0 or cursor 0 = bootstrap; else resume after this seq of that log incarnation; wire = newest delta format the subscriber reads, legacy 16-byte payloads imply 2) |
//! | `REPLICA_ACK`     | cursor u64 (highest replication seq applied)          |
//! | `METRICS_DUMP`    | empty                                                 |
//! | `TRACE_DUMP`      | empty                                                 |
//!
//! An `INSERT_BATCH` payload may carry an optional trailing 16-byte
//! **trace context** — trace_id u64 · flags u64 (bit 0 = sampled, both
//! LE; see [`crate::obs::encode_trace_ctx`]) — appended after the word
//! array. The server peels it before strict decoding; clients only
//! stamp it after probing that the server answers `TRACE_DUMP` (old
//! servers reject the longer payload as malformed, and the probe is how
//! a client discovers it must stay untraced).
//!
//! # Response payloads
//!
//! | opcode                  | payload                                        |
//! |-------------------------|------------------------------------------------|
//! | `PONG`                  | empty                                          |
//! | `INGESTED`              | words u64                                      |
//! | `ESTIMATE_REPLY`        | present u8 (0/1) · estimate f64 bits u64       |
//! | `GLOBAL_ESTIMATE_REPLY` | present u8 (0/1) · estimate f64 bits u64       |
//! | `MERGED`                | empty                                          |
//! | `STATS_REPLY`           | keys · sparse · packed · dense · memory_bytes · words (6 × u64) · estimator u8 |
//! | `EVICTED`               | keys u64                                       |
//! | `SNAPSHOT_DONE`         | keys u64 · file bytes u64                      |
//! | `FULL_SYNC`             | epoch u64 · cursor u64 · len u32 · len × snapshot-format bytes |
//! | `DELTA_BATCH`           | seq u64 · count u32 · count × (key u64 · len u32 · sketch wire-v2 bytes) |
//! | `DELTA_BATCH_V3`        | seq u64 · count u32 · count × (key u64 · kind u8 · len u32 · len × body) |
//! | `METRICS_TEXT`          | len u32 · len × utf-8 exposition bytes         |
//! | `TRACE_EVENTS`          | version u8 (1) · count u32 · count × (ns u64 · trace_id u64 · payload u64 · stage u8 · kind u8) |
//! | `ERROR`                 | code u8 · msg_len u32 · msg_len × utf-8 bytes  |
//!
//! # Replication frames
//!
//! `SUBSCRIBE` flips a connection into a replication stream (see
//! [`crate::replica`]): the primary answers with a `FULL_SYNC` when the
//! cursor is 0 (bootstrap), carries an epoch from a different log
//! incarnation (a restarted primary resets seq numbering — the epoch
//! is what makes the reset detectable), or is no longer covered by the
//! retained delta log; then it streams `DELTA_BATCH_V3` frames as the
//! capture thread seals them. The follower sends `REPLICA_ACK` frames
//! back on the same socket (the primary bounds unacked batches in
//! flight). A `FULL_SYNC`
//! body is one complete in-memory snapshot image (the `HLLSNAP2` format
//! of [`super::snapshot`], global-union record included), so it is
//! subject to the [`MAX_PAYLOAD`] frame cap — registries whose image
//! exceeds it must bootstrap followers from a snapshot file instead.
//!
//! `DELTA_BATCH_V3` is the wire-v3 delta entry format: each entry is
//! typed by a `kind` byte (see [`delta_kind`]) —
//!
//! | kind | name           | body                                           |
//! |------|----------------|------------------------------------------------|
//! | 0    | `FULL`         | the key's full sketch, wire format v2          |
//! | 1    | `REGISTER_DIFF`| changed registers, [`crate::hll::encode_register_diff`] format |
//! | 2    | `TOMBSTONE`    | empty (`len` must be 0) — the key was evicted  |
//! | 3    | `GLOBAL_DIFF`  | changed registers of the *global union* sketch (key field ignored, encoded 0) |
//! | 4    | `SEAL_TS`      | wall-clock seal timestamp, unix ns u64 (key 0; batch metadata, not a delta) |
//! | 5    | `TRACE_IDS`    | n × trace_id u64 — last-writer trace IDs of the batch (key 0; metadata; wire v4+ only) |
//!
//! Followers apply a batch's entries **in order**: a key evicted and
//! re-created between captures arrives as a tombstone immediately
//! followed by its new full sketch, which is what keeps follower state
//! from max-merging the dead incarnation into the new one. The legacy
//! `DELTA_BATCH` (wire v2: every entry a full sketch, evictions never
//! shipped) is still decoded for compatibility with v2 primaries, but
//! this server only ever *sends* v3.
//!
//! The `MERGE_SKETCH` body reuses the seed-carrying sketch wire format v2
//! (see [`crate::hll::sketch`]), so a sketch built with a nonzero hash
//! seed cannot silently merge into a differently-seeded registry over the
//! network: the server answers an `ERROR` frame with
//! [`ErrorCode::ConfigMismatch`].
//!
//! Decoding is strict: short payloads, trailing bytes, unknown opcodes,
//! bad magic/version and oversized length fields all fail with a typed
//! [`ProtocolError`] — never a panic — so a hostile or corrupted peer
//! cannot take the server down.

use std::io::{self, Read};

use crate::obs::trace::{decode_trace_ctx, TraceEvent, TRACE_CTX_LEN, TRACE_EVENT_WIRE_LEN};
use crate::registry::{RegistryStats, SketchDelta};

/// Frame magic: ASCII "HL".
pub const MAGIC: [u8; 2] = *b"HL";
/// Protocol version carried in every frame header.
pub const PROTO_VERSION: u8 = 1;
/// Fixed frame header length: magic(2) + version(1) + opcode(1) + len(4).
pub const FRAME_HEADER_LEN: usize = 8;
/// Upper bound on the payload length accepted from the wire, guarding a
/// corrupted or hostile length field from driving a giant allocation.
pub const MAX_PAYLOAD: u32 = 64 << 20;

/// Frame opcodes. Requests use the low range, responses the high range.
pub mod opcodes {
    pub const PING: u8 = 0x01;
    pub const INSERT_BATCH: u8 = 0x02;
    pub const ESTIMATE: u8 = 0x03;
    pub const GLOBAL_ESTIMATE: u8 = 0x04;
    pub const MERGE_SKETCH: u8 = 0x05;
    pub const STATS: u8 = 0x06;
    pub const EVICT: u8 = 0x07;
    pub const SNAPSHOT: u8 = 0x08;
    pub const SUBSCRIBE: u8 = 0x09;
    pub const REPLICA_ACK: u8 = 0x0A;
    pub const METRICS_DUMP: u8 = 0x0B;
    pub const TRACE_DUMP: u8 = 0x0C;

    pub const PONG: u8 = 0x81;
    pub const INGESTED: u8 = 0x82;
    pub const ESTIMATE_REPLY: u8 = 0x83;
    pub const GLOBAL_ESTIMATE_REPLY: u8 = 0x84;
    pub const MERGED: u8 = 0x85;
    pub const STATS_REPLY: u8 = 0x86;
    pub const EVICTED: u8 = 0x87;
    pub const SNAPSHOT_DONE: u8 = 0x88;
    pub const FULL_SYNC: u8 = 0x89;
    pub const DELTA_BATCH: u8 = 0x8A;
    pub const DELTA_BATCH_V3: u8 = 0x8B;
    pub const METRICS_TEXT: u8 = 0x8C;
    pub const TRACE_EVENTS: u8 = 0x8D;
    pub const ERROR: u8 = 0xEE;
}

/// Highest request opcode, bounding the server's per-opcode metric
/// arrays (requests are contiguous from [`opcodes::PING`]).
pub const REQUEST_OPCODE_MAX: u8 = opcodes::TRACE_DUMP;

/// Human-readable label of a request opcode, used as the `op` metric
/// label on per-opcode latency/size series. Stable static strings so
/// registering series per opcode is allocation-free.
pub fn request_opcode_name(opcode: u8) -> &'static str {
    match opcode {
        opcodes::PING => "ping",
        opcodes::INSERT_BATCH => "insert_batch",
        opcodes::ESTIMATE => "estimate",
        opcodes::GLOBAL_ESTIMATE => "global_estimate",
        opcodes::MERGE_SKETCH => "merge_sketch",
        opcodes::STATS => "stats",
        opcodes::EVICT => "evict",
        opcodes::SNAPSHOT => "snapshot",
        opcodes::SUBSCRIBE => "subscribe",
        opcodes::REPLICA_ACK => "replica_ack",
        opcodes::METRICS_DUMP => "metrics_dump",
        opcodes::TRACE_DUMP => "trace_dump",
        _ => "unknown",
    }
}

/// Entry kind tags of the `DELTA_BATCH_V3` payload (wire-v3 delta
/// entries; see the module docs).
pub mod delta_kind {
    /// Body is the key's full sketch in wire format v2.
    pub const FULL: u8 = 0;
    /// Body is a changed-register diff
    /// ([`crate::hll::encode_register_diff`] format).
    pub const REGISTER_DIFF: u8 = 1;
    /// No body: the key was evicted on the primary.
    pub const TOMBSTONE: u8 = 2;
    /// Body is a changed-register diff of the primary's *global union*
    /// sketch (same codec as `REGISTER_DIFF`); the entry's key field is
    /// meaningless and encoded as 0. This is what carries words whose
    /// key was evicted before the capture tick into followers'
    /// `GlobalEstimate`.
    pub const GLOBAL_DIFF: u8 = 3;
    /// Body is the batch's wall-clock seal timestamp (unix nanoseconds,
    /// u64 LE, so `len` must be 8); the key field is meaningless and
    /// encoded as 0. Batch *metadata*, not a delta: followers use it to
    /// measure seal-to-apply replication latency and never merge it.
    /// At most one per batch, appended last by the encoder.
    pub const SEAL_TS: u8 = 4;
    /// Body is `n` trace IDs (u64 LE each, so `len` must be a multiple
    /// of 8): the last-writer trace IDs deposited while this batch's
    /// deltas accumulated, letting a follower stitch its apply span
    /// onto the primary-side traces. Key field meaningless, encoded 0.
    /// Batch *metadata* like `SEAL_TS`, never merged. Only sent to
    /// subscribers that negotiated [`DELTA_WIRE_V4`](super::DELTA_WIRE_V4)
    /// or newer — wire-v3 decoders reject unknown kinds.
    pub const TRACE_IDS: u8 = 5;
}

/// Fixed wire overhead of one `DELTA_BATCH_V3` entry: key (8) + kind
/// (1) + body length (4). The replication log uses it for batch-size
/// accounting so an encoded frame can never outgrow what the log
/// budgeted.
pub const DELTA_ENTRY_OVERHEAD: usize = 13;

/// Delta wire generation a subscriber may request in `SUBSCRIBE`:
/// legacy full-sketch-only `DELTA_BATCH` entries. A 16-byte (pre-wire-
/// field) `SUBSCRIBE` payload decodes as this, so old followers keep
/// working against new primaries — they get v2 frames with register
/// diffs inflated to full sketches and tombstones dropped (grow-only,
/// exactly the semantics they were built for).
pub const DELTA_WIRE_V2: u8 = 2;

/// Delta wire generation with typed entries (`DELTA_BATCH_V3`):
/// register diffs and eviction tombstones.
pub const DELTA_WIRE_V3: u8 = 3;

/// Delta wire generation adding the `TRACE_IDS` metadata entry to
/// `DELTA_BATCH_V3` frames (same frame opcode; one more entry kind).
/// What current followers request. A v3 subscriber never sees the new
/// kind — its strict decoder treats unknown kinds as malformed — and a
/// v3 *primary* simply ignores the higher requested generation and
/// streams plain v3, so either side may be upgraded first.
pub const DELTA_WIRE_V4: u8 = 4;

/// Version byte leading a `TRACE_EVENTS` response payload; bump when
/// the event record grows.
pub const TRACE_EVENTS_VERSION: u8 = 1;

/// Most trace IDs one `TRACE_IDS` metadata entry may carry — bounds
/// both the log's deposit slots and the decoder's tolerance for a
/// hostile length field.
pub const MAX_WRITER_TRACES: usize = 16;

/// Errors reading or decoding a frame.
#[derive(Debug)]
pub enum ProtocolError {
    Io(io::Error),
    BadMagic([u8; 2]),
    BadVersion(u8),
    BadOpcode(u8),
    Oversize(u32),
    Malformed(String),
}

impl std::fmt::Display for ProtocolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtocolError::Io(e) => write!(f, "io error: {e}"),
            ProtocolError::BadMagic(m) => write!(f, "bad frame magic {m:02x?}"),
            ProtocolError::BadVersion(v) => {
                write!(f, "unsupported protocol version {v} (expected {PROTO_VERSION})")
            }
            ProtocolError::BadOpcode(op) => write!(f, "unknown opcode 0x{op:02x}"),
            ProtocolError::Oversize(n) => {
                write!(f, "payload length {n} exceeds the {MAX_PAYLOAD}-byte cap")
            }
            ProtocolError::Malformed(what) => write!(f, "malformed payload: {what}"),
        }
    }
}

impl std::error::Error for ProtocolError {}

impl From<io::Error> for ProtocolError {
    fn from(e: io::Error) -> Self {
        ProtocolError::Io(e)
    }
}

/// Error codes carried by `ERROR` response frames.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// The request could not be decoded or referenced invalid bytes.
    Malformed = 1,
    /// A merged sketch's config (p / hash width / seed) does not match
    /// the registry's.
    ConfigMismatch = 2,
    /// The server does not support the operation (e.g. `SNAPSHOT` on a
    /// server started without a snapshot path, or `SUBSCRIBE` on a
    /// server that is not a replication primary).
    Unsupported = 3,
    /// The operation failed server-side (e.g. snapshot disk I/O).
    Internal = 4,
    /// The server is a read-only replica; mutating RPCs must go to the
    /// primary (see [`crate::replica::FollowerServer`]).
    ReadOnly = 5,
}

impl ErrorCode {
    pub fn from_u8(v: u8) -> Option<ErrorCode> {
        match v {
            1 => Some(ErrorCode::Malformed),
            2 => Some(ErrorCode::ConfigMismatch),
            3 => Some(ErrorCode::Unsupported),
            4 => Some(ErrorCode::Internal),
            5 => Some(ErrorCode::ReadOnly),
            _ => None,
        }
    }
}

/// Eviction policy selector of the `EVICT` request — the RPC knob over
/// the registry's eviction primitives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictPolicy {
    /// Drop one key.
    Key(u64),
    /// TTL sweep: drop keys idle for more than `max_age` clock ticks
    /// ([`crate::registry::SketchRegistry::evict_idle`]).
    Idle { max_age: u64 },
    /// LRU size budget: evict least-recently-touched keys until total
    /// sketch heap is at most `max_memory_bytes`
    /// ([`crate::registry::SketchRegistry::evict_to_budget`]).
    Budget { max_memory_bytes: u64 },
    /// Wall-clock TTL sweep: drop keys idle for more than `max_age_secs`
    /// seconds of real time
    /// ([`crate::registry::SketchRegistry::evict_idle_wall`]).
    IdleWall { max_age_secs: u64 },
}

/// A client→server request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Ping,
    InsertBatch { key: u64, words: Vec<u32> },
    Estimate { key: u64 },
    GlobalEstimate,
    MergeSketch { key: u64, bytes: Vec<u8> },
    Stats,
    Evict(EvictPolicy),
    Snapshot,
    /// Flip this connection into a replication stream, resuming after
    /// replication seq `cursor` of log incarnation `epoch` (epoch 0 or
    /// cursor 0 = fresh follower, bootstrap me; an epoch that is not
    /// the primary's current one also forces a bootstrap). `wire` is
    /// the newest delta wire generation the subscriber understands
    /// ([`DELTA_WIRE_V2`] / [`DELTA_WIRE_V3`]); the primary streams at
    /// `min(wire, v3)`, downgrading typed entries for legacy
    /// subscribers. A legacy 16-byte payload (no wire field) decodes
    /// as [`DELTA_WIRE_V2`].
    Subscribe { epoch: u64, cursor: u64, wire: u8 },
    /// Follower → primary on a subscription stream: everything up to
    /// `cursor` has been applied (feeds the primary's ack window).
    ReplicaAck { cursor: u64 },
    /// Scrape the server's metrics registry; answered with
    /// [`Response::MetricsText`] (the versioned text exposition).
    /// Allowed on read-only replicas — observability is not a mutation.
    MetricsDump,
    /// Dump the flight recorder's recent trace events; answered with
    /// [`Response::TraceEvents`]. Allowed on read-only replicas.
    /// Doubles as the client's tracing-capability probe: servers
    /// predating it answer a typed `BadOpcode` error.
    TraceDump,
}

/// Registry accounting totals, flattened for the wire: per-tier key
/// counts (sparse/packed/dense partition `keys`), heap bytes, ingested
/// words, and which estimator ([`crate::hll::EstimatorKind`] wire byte)
/// answers the registry's estimate queries.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct StatsSummary {
    pub keys: u64,
    pub sparse_keys: u64,
    pub packed_keys: u64,
    pub dense_keys: u64,
    pub memory_bytes: u64,
    pub words: u64,
    /// [`crate::hll::EstimatorKind`] as its wire byte (0 = Ertl,
    /// 1 = Legacy).
    pub estimator: u8,
}

impl From<&RegistryStats> for StatsSummary {
    fn from(s: &RegistryStats) -> Self {
        Self {
            keys: s.keys() as u64,
            sparse_keys: s.sparse_keys() as u64,
            packed_keys: s.packed_keys() as u64,
            dense_keys: s.dense_keys() as u64,
            memory_bytes: s.memory_bytes() as u64,
            words: s.words(),
            estimator: s.estimator().as_wire_byte(),
        }
    }
}

/// A server→client response.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Pong,
    Ingested { words: u64 },
    Estimate(Option<f64>),
    GlobalEstimate(Option<f64>),
    Merged,
    Stats(StatsSummary),
    Evicted { keys: u64 },
    SnapshotDone { keys: u64, bytes: u64 },
    /// Primary → follower: a complete registry image in the snapshot
    /// byte format ([`super::snapshot`], `HLLSNAP2`); after applying it
    /// the follower's replication position is `cursor` within log
    /// incarnation `epoch` (the pair it must resume with later).
    FullSync { epoch: u64, cursor: u64, body: Vec<u8> },
    /// Primary → follower, legacy wire v2: one sealed batch of per-key
    /// sketch frames (every entry a full sketch; evictions never
    /// shipped). Decoded for compatibility with old primaries; this
    /// server only sends [`Response::DeltaBatchV3`].
    DeltaBatch { seq: u64, entries: Vec<(u64, Vec<u8>)> },
    /// Primary → follower, wire v3: one sealed batch of typed delta
    /// entries (tombstone / register diff / full sketch — see
    /// [`delta_kind`] and the module docs). Diff and full entries are
    /// idempotent max-merges; entries must be applied in order so
    /// tombstones sequence correctly against re-created keys.
    /// `seal_unix_ns` is the batch's wall-clock seal timestamp (0 =
    /// absent, e.g. frames from a pre-observability primary), carried
    /// on the wire as a trailing [`delta_kind::SEAL_TS`] entry so the
    /// follower can measure seal-to-apply replication latency.
    /// `writer_traces` holds the last-writer trace IDs deposited while
    /// the batch accumulated (empty = untraced or pre-v4 peer), carried
    /// as a [`delta_kind::TRACE_IDS`] metadata entry on wire v4+ so the
    /// follower's apply span joins the primary-side traces.
    DeltaBatchV3 {
        seq: u64,
        entries: Vec<(u64, SketchDelta)>,
        seal_unix_ns: u64,
        writer_traces: Vec<u64>,
    },
    /// The metrics registry's text exposition (see
    /// [`crate::obs::MetricsRegistry::render`]): versioned header line
    /// plus sorted `name{label="v"} value` lines. Strictly utf-8 on the
    /// wire — hostile bytes fail decode with a typed error.
    MetricsText(String),
    /// The flight recorder's recent events (see
    /// [`crate::obs::recorder::snapshot`]), versioned so the event
    /// record can grow: payload is version u8 (currently 1) + count u32
    /// + count fixed-size event records.
    TraceEvents { events: Vec<TraceEvent> },
    Error { code: ErrorCode, message: String },
}

// ---------------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------------

fn frame(opcode: u8, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(FRAME_HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC);
    out.push(PROTO_VERSION);
    out.push(opcode);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Encode a legacy `DELTA_BATCH` (wire v2) frame from borrowed entries.
pub fn encode_delta_batch(seq: u64, entries: &[(u64, Vec<u8>)]) -> Vec<u8> {
    let payload_len = 12 + entries.iter().map(|(_, b)| 12 + b.len()).sum::<usize>();
    let mut payload = Vec::with_capacity(payload_len);
    payload.extend_from_slice(&seq.to_le_bytes());
    payload.extend_from_slice(&(entries.len() as u32).to_le_bytes());
    for (key, bytes) in entries {
        payload.extend_from_slice(&key.to_le_bytes());
        payload.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
        payload.extend_from_slice(bytes);
    }
    frame(opcodes::DELTA_BATCH, &payload)
}

/// Encode a `DELTA_BATCH_V3` frame straight from a sealed batch's
/// borrowed typed entries — the primary's subscriber-streaming hot path
/// (batches are shared `Arc`s across subscribers; no entry clone per
/// send). Never emits the wire-v4 `TRACE_IDS` entry; use
/// [`encode_delta_batch_v4`] for subscribers that negotiated it.
pub fn encode_delta_batch_v3(
    seq: u64,
    entries: &[(u64, SketchDelta)],
    seal_unix_ns: u64,
) -> Vec<u8> {
    encode_delta_batch_typed(seq, entries, seal_unix_ns, &[])
}

/// Encode a wire-v4 delta batch: a `DELTA_BATCH_V3` frame that may
/// additionally carry the batch's last-writer trace IDs as a trailing
/// [`delta_kind::TRACE_IDS`] metadata entry. Only for subscribers that
/// negotiated [`DELTA_WIRE_V4`] — a v3 decoder rejects the new kind.
pub fn encode_delta_batch_v4(
    seq: u64,
    entries: &[(u64, SketchDelta)],
    seal_unix_ns: u64,
    writer_traces: &[u64],
) -> Vec<u8> {
    encode_delta_batch_typed(seq, entries, seal_unix_ns, writer_traces)
}

fn encode_delta_batch_typed(
    seq: u64,
    entries: &[(u64, SketchDelta)],
    seal_unix_ns: u64,
    writer_traces: &[u64],
) -> Vec<u8> {
    let seal = if seal_unix_ns != 0 { 1usize } else { 0 };
    let traces = if writer_traces.is_empty() { 0usize } else { 1 };
    let payload_len = 12
        + entries.iter().map(|(_, d)| DELTA_ENTRY_OVERHEAD + d.body_len()).sum::<usize>()
        + seal * (DELTA_ENTRY_OVERHEAD + 8)
        + traces * (DELTA_ENTRY_OVERHEAD + writer_traces.len() * 8);
    let mut payload = Vec::with_capacity(payload_len);
    payload.extend_from_slice(&seq.to_le_bytes());
    payload.extend_from_slice(&((entries.len() + seal + traces) as u32).to_le_bytes());
    for (key, delta) in entries {
        payload.extend_from_slice(&key.to_le_bytes());
        let (kind, body): (u8, &[u8]) = match delta {
            SketchDelta::Full(b) => (delta_kind::FULL, b.as_slice()),
            SketchDelta::RegisterDiff(b) => (delta_kind::REGISTER_DIFF, b.as_slice()),
            SketchDelta::Tombstone => (delta_kind::TOMBSTONE, &[]),
            SketchDelta::GlobalDiff(b) => (delta_kind::GLOBAL_DIFF, b.as_slice()),
        };
        payload.push(kind);
        payload.extend_from_slice(&(body.len() as u32).to_le_bytes());
        payload.extend_from_slice(body);
    }
    // Trailing metadata entries (seal timestamp, then writer trace
    // IDs). Appended last so legacy-minded decoders that apply in order
    // see all real deltas first.
    if seal != 0 {
        payload.extend_from_slice(&0u64.to_le_bytes());
        payload.push(delta_kind::SEAL_TS);
        payload.extend_from_slice(&8u32.to_le_bytes());
        payload.extend_from_slice(&seal_unix_ns.to_le_bytes());
    }
    if traces != 0 {
        payload.extend_from_slice(&0u64.to_le_bytes());
        payload.push(delta_kind::TRACE_IDS);
        payload.extend_from_slice(&((writer_traces.len() * 8) as u32).to_le_bytes());
        for id in writer_traces {
            payload.extend_from_slice(&id.to_le_bytes());
        }
    }
    frame(opcodes::DELTA_BATCH_V3, &payload)
}

/// Encode an `INSERT_BATCH` frame straight from borrowed words — the
/// client's pipelining hot path (no intermediate [`Request`] allocation).
pub fn encode_insert_batch(key: u64, words: &[u32]) -> Vec<u8> {
    let mut payload = Vec::with_capacity(12 + words.len() * 4);
    payload.extend_from_slice(&key.to_le_bytes());
    payload.extend_from_slice(&(words.len() as u32).to_le_bytes());
    for &w in words {
        payload.extend_from_slice(&w.to_le_bytes());
    }
    frame(opcodes::INSERT_BATCH, &payload)
}

/// Encode an `INSERT_BATCH` frame with the 16-byte trailing trace
/// context (see the module docs). Only send to servers that answered
/// the `TRACE_DUMP` probe — older servers reject the longer payload.
pub fn encode_insert_batch_traced(key: u64, words: &[u32], trace_id: u64) -> Vec<u8> {
    let mut payload = Vec::with_capacity(12 + words.len() * 4 + TRACE_CTX_LEN);
    payload.extend_from_slice(&key.to_le_bytes());
    payload.extend_from_slice(&(words.len() as u32).to_le_bytes());
    for &w in words {
        payload.extend_from_slice(&w.to_le_bytes());
    }
    payload.extend_from_slice(&crate::obs::trace::encode_trace_ctx(trace_id));
    frame(opcodes::INSERT_BATCH, &payload)
}

/// Split an inbound request payload into (body, trace id): if `opcode`
/// supports the trailing trace-context extension and `payload` carries
/// a well-formed one *past its exact expected body length*, return the
/// body with the trailer peeled and the decoded trace ID. Everything
/// else passes through untouched, so strict request decoding (and its
/// error behavior for hostile frames) is exactly what it was before
/// trace contexts existed.
pub fn split_trace_ctx(opcode: u8, payload: &[u8]) -> (&[u8], Option<u64>) {
    if opcode != opcodes::INSERT_BATCH || payload.len() < 12 + TRACE_CTX_LEN {
        return (payload, None);
    }
    // Body length is fully determined by the declared word count, so a
    // 16-byte surplus is unambiguous.
    let count = u32::from_le_bytes(payload[8..12].try_into().expect("len checked")) as u64;
    let expect = 12 + count * 4;
    if payload.len() as u64 != expect + TRACE_CTX_LEN as u64 {
        return (payload, None);
    }
    let split = expect as usize;
    match decode_trace_ctx(&payload[split..]) {
        Some(id) => (&payload[..split], Some(id)),
        None => (payload, None),
    }
}

impl Request {
    /// Serialize to one complete frame (header + payload).
    pub fn encode(&self) -> Vec<u8> {
        match self {
            Request::Ping => frame(opcodes::PING, &[]),
            Request::InsertBatch { key, words } => encode_insert_batch(*key, words),
            Request::Estimate { key } => frame(opcodes::ESTIMATE, &key.to_le_bytes()),
            Request::GlobalEstimate => frame(opcodes::GLOBAL_ESTIMATE, &[]),
            Request::MergeSketch { key, bytes } => {
                let mut payload = Vec::with_capacity(12 + bytes.len());
                payload.extend_from_slice(&key.to_le_bytes());
                payload.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
                payload.extend_from_slice(bytes);
                frame(opcodes::MERGE_SKETCH, &payload)
            }
            Request::Stats => frame(opcodes::STATS, &[]),
            Request::Evict(policy) => {
                let (tag, arg) = match policy {
                    EvictPolicy::Key(key) => (0u8, *key),
                    EvictPolicy::Idle { max_age } => (1, *max_age),
                    EvictPolicy::Budget { max_memory_bytes } => (2, *max_memory_bytes),
                    EvictPolicy::IdleWall { max_age_secs } => (3, *max_age_secs),
                };
                let mut payload = Vec::with_capacity(9);
                payload.push(tag);
                payload.extend_from_slice(&arg.to_le_bytes());
                frame(opcodes::EVICT, &payload)
            }
            Request::Snapshot => frame(opcodes::SNAPSHOT, &[]),
            Request::Subscribe { epoch, cursor, wire } => {
                let mut payload = Vec::with_capacity(17);
                payload.extend_from_slice(&epoch.to_le_bytes());
                payload.extend_from_slice(&cursor.to_le_bytes());
                payload.push(*wire);
                frame(opcodes::SUBSCRIBE, &payload)
            }
            Request::ReplicaAck { cursor } => {
                frame(opcodes::REPLICA_ACK, &cursor.to_le_bytes())
            }
            Request::MetricsDump => frame(opcodes::METRICS_DUMP, &[]),
            Request::TraceDump => frame(opcodes::TRACE_DUMP, &[]),
        }
    }

    /// Decode a request payload for `opcode`. Strict: trailing or missing
    /// bytes are a typed error.
    pub fn decode(opcode: u8, payload: &[u8]) -> Result<Request, ProtocolError> {
        let mut r = Reader::new(payload);
        let req = match opcode {
            opcodes::PING => Request::Ping,
            opcodes::INSERT_BATCH => {
                let key = r.u64()?;
                let count = r.u32()?;
                // Compare in u64: `count as usize * 4` could wrap on a
                // 32-bit target, letting a hostile count pass the check
                // and drive a huge allocation below.
                if r.remaining() as u64 != count as u64 * 4 {
                    return Err(ProtocolError::Malformed(format!(
                        "insert batch declares {count} words but carries {} payload bytes",
                        r.remaining()
                    )));
                }
                let mut words = Vec::with_capacity(count as usize);
                for _ in 0..count {
                    words.push(r.u32()?);
                }
                Request::InsertBatch { key, words }
            }
            opcodes::ESTIMATE => Request::Estimate { key: r.u64()? },
            opcodes::GLOBAL_ESTIMATE => Request::GlobalEstimate,
            opcodes::MERGE_SKETCH => {
                let key = r.u64()?;
                let len = r.u32()? as usize;
                let bytes = r.bytes(len)?.to_vec();
                Request::MergeSketch { key, bytes }
            }
            opcodes::STATS => Request::Stats,
            opcodes::EVICT => {
                let tag = r.u8()?;
                let arg = r.u64()?;
                let policy = match tag {
                    0 => EvictPolicy::Key(arg),
                    1 => EvictPolicy::Idle { max_age: arg },
                    2 => EvictPolicy::Budget { max_memory_bytes: arg },
                    3 => EvictPolicy::IdleWall { max_age_secs: arg },
                    other => {
                        return Err(ProtocolError::Malformed(format!(
                            "unknown evict policy {other}"
                        )))
                    }
                };
                Request::Evict(policy)
            }
            opcodes::SNAPSHOT => Request::Snapshot,
            opcodes::SUBSCRIBE => {
                let epoch = r.u64()?;
                let cursor = r.u64()?;
                // Pre-wire-field subscribers (16-byte payload) speak
                // the legacy full-sketch delta format.
                let wire = if r.remaining() == 0 { DELTA_WIRE_V2 } else { r.u8()? };
                if wire < DELTA_WIRE_V2 {
                    return Err(ProtocolError::Malformed(format!(
                        "subscriber delta wire {wire} predates the oldest supported ({DELTA_WIRE_V2})"
                    )));
                }
                Request::Subscribe { epoch, cursor, wire }
            }
            opcodes::REPLICA_ACK => Request::ReplicaAck { cursor: r.u64()? },
            opcodes::METRICS_DUMP => Request::MetricsDump,
            opcodes::TRACE_DUMP => Request::TraceDump,
            other => return Err(ProtocolError::BadOpcode(other)),
        };
        r.finish()?;
        Ok(req)
    }
}

fn encode_opt_f64(payload: &mut Vec<u8>, v: Option<f64>) {
    payload.push(v.is_some() as u8);
    payload.extend_from_slice(&v.unwrap_or(0.0).to_bits().to_le_bytes());
}

fn decode_opt_f64(r: &mut Reader<'_>) -> Result<Option<f64>, ProtocolError> {
    let present = match r.u8()? {
        0 => false,
        1 => true,
        other => {
            return Err(ProtocolError::Malformed(format!("estimate presence flag {other}")))
        }
    };
    let bits = r.u64()?;
    Ok(present.then(|| f64::from_bits(bits)))
}

impl Response {
    /// Short variant name, for "expected X, got Y" client errors.
    pub fn label(&self) -> &'static str {
        match self {
            Response::Pong => "Pong",
            Response::Ingested { .. } => "Ingested",
            Response::Estimate(_) => "Estimate",
            Response::GlobalEstimate(_) => "GlobalEstimate",
            Response::Merged => "Merged",
            Response::Stats(_) => "Stats",
            Response::Evicted { .. } => "Evicted",
            Response::SnapshotDone { .. } => "SnapshotDone",
            Response::FullSync { .. } => "FullSync",
            Response::DeltaBatch { .. } => "DeltaBatch",
            Response::DeltaBatchV3 { .. } => "DeltaBatchV3",
            Response::MetricsText(_) => "MetricsText",
            Response::TraceEvents { .. } => "TraceEvents",
            Response::Error { .. } => "Error",
        }
    }

    /// Serialize to one complete frame (header + payload).
    pub fn encode(&self) -> Vec<u8> {
        match self {
            Response::Pong => frame(opcodes::PONG, &[]),
            Response::Ingested { words } => frame(opcodes::INGESTED, &words.to_le_bytes()),
            Response::Estimate(v) => {
                let mut payload = Vec::with_capacity(9);
                encode_opt_f64(&mut payload, *v);
                frame(opcodes::ESTIMATE_REPLY, &payload)
            }
            Response::GlobalEstimate(v) => {
                let mut payload = Vec::with_capacity(9);
                encode_opt_f64(&mut payload, *v);
                frame(opcodes::GLOBAL_ESTIMATE_REPLY, &payload)
            }
            Response::Merged => frame(opcodes::MERGED, &[]),
            Response::Stats(s) => {
                let mut payload = Vec::with_capacity(49);
                for v in [
                    s.keys,
                    s.sparse_keys,
                    s.packed_keys,
                    s.dense_keys,
                    s.memory_bytes,
                    s.words,
                ] {
                    payload.extend_from_slice(&v.to_le_bytes());
                }
                payload.push(s.estimator);
                frame(opcodes::STATS_REPLY, &payload)
            }
            Response::Evicted { keys } => frame(opcodes::EVICTED, &keys.to_le_bytes()),
            Response::SnapshotDone { keys, bytes } => {
                let mut payload = Vec::with_capacity(16);
                payload.extend_from_slice(&keys.to_le_bytes());
                payload.extend_from_slice(&bytes.to_le_bytes());
                frame(opcodes::SNAPSHOT_DONE, &payload)
            }
            Response::FullSync { epoch, cursor, body } => {
                let mut payload = Vec::with_capacity(20 + body.len());
                payload.extend_from_slice(&epoch.to_le_bytes());
                payload.extend_from_slice(&cursor.to_le_bytes());
                payload.extend_from_slice(&(body.len() as u32).to_le_bytes());
                payload.extend_from_slice(body);
                frame(opcodes::FULL_SYNC, &payload)
            }
            Response::DeltaBatch { seq, entries } => encode_delta_batch(*seq, entries),
            Response::DeltaBatchV3 { seq, entries, seal_unix_ns, writer_traces } => {
                encode_delta_batch_typed(*seq, entries, *seal_unix_ns, writer_traces)
            }
            Response::MetricsText(text) => {
                let bytes = text.as_bytes();
                let mut payload = Vec::with_capacity(4 + bytes.len());
                payload.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
                payload.extend_from_slice(bytes);
                frame(opcodes::METRICS_TEXT, &payload)
            }
            Response::TraceEvents { events } => {
                let mut payload =
                    Vec::with_capacity(5 + events.len() * TRACE_EVENT_WIRE_LEN);
                payload.push(TRACE_EVENTS_VERSION);
                payload.extend_from_slice(&(events.len() as u32).to_le_bytes());
                for e in events {
                    payload.extend_from_slice(&e.ns.to_le_bytes());
                    payload.extend_from_slice(&e.trace_id.to_le_bytes());
                    payload.extend_from_slice(&e.payload.to_le_bytes());
                    payload.push(e.stage);
                    payload.push(e.kind);
                }
                frame(opcodes::TRACE_EVENTS, &payload)
            }
            Response::Error { code, message } => {
                let msg = message.as_bytes();
                let mut payload = Vec::with_capacity(5 + msg.len());
                payload.push(*code as u8);
                payload.extend_from_slice(&(msg.len() as u32).to_le_bytes());
                payload.extend_from_slice(msg);
                frame(opcodes::ERROR, &payload)
            }
        }
    }

    /// Decode a response payload for `opcode`.
    pub fn decode(opcode: u8, payload: &[u8]) -> Result<Response, ProtocolError> {
        let mut r = Reader::new(payload);
        let resp = match opcode {
            opcodes::PONG => Response::Pong,
            opcodes::INGESTED => Response::Ingested { words: r.u64()? },
            opcodes::ESTIMATE_REPLY => Response::Estimate(decode_opt_f64(&mut r)?),
            opcodes::GLOBAL_ESTIMATE_REPLY => Response::GlobalEstimate(decode_opt_f64(&mut r)?),
            opcodes::MERGED => Response::Merged,
            opcodes::STATS_REPLY => Response::Stats(StatsSummary {
                keys: r.u64()?,
                sparse_keys: r.u64()?,
                packed_keys: r.u64()?,
                dense_keys: r.u64()?,
                memory_bytes: r.u64()?,
                words: r.u64()?,
                estimator: r.u8()?,
            }),
            opcodes::EVICTED => Response::Evicted { keys: r.u64()? },
            opcodes::SNAPSHOT_DONE => {
                Response::SnapshotDone { keys: r.u64()?, bytes: r.u64()? }
            }
            opcodes::FULL_SYNC => {
                let epoch = r.u64()?;
                let cursor = r.u64()?;
                let len = r.u32()? as usize;
                let body = r.bytes(len)?.to_vec();
                Response::FullSync { epoch, cursor, body }
            }
            opcodes::DELTA_BATCH => {
                let seq = r.u64()?;
                let count = r.u32()?;
                // Every entry needs at least its 12-byte header; checking
                // up front (in u64, so a hostile count cannot wrap) keeps
                // `with_capacity` from pre-allocating for a count the
                // payload cannot possibly carry.
                if (r.remaining() as u64) < count as u64 * 12 {
                    return Err(ProtocolError::Malformed(format!(
                        "delta batch declares {count} entries but carries {} payload bytes",
                        r.remaining()
                    )));
                }
                let mut entries = Vec::with_capacity(count as usize);
                for _ in 0..count {
                    let key = r.u64()?;
                    let len = r.u32()? as usize;
                    entries.push((key, r.bytes(len)?.to_vec()));
                }
                Response::DeltaBatch { seq, entries }
            }
            opcodes::DELTA_BATCH_V3 => {
                let seq = r.u64()?;
                let count = r.u32()?;
                // Same alloc guard as DELTA_BATCH: every entry needs at
                // least its 13-byte header, checked in u64 up front so a
                // hostile count cannot wrap the multiply or drive
                // `with_capacity`.
                if (r.remaining() as u64) < count as u64 * DELTA_ENTRY_OVERHEAD as u64 {
                    return Err(ProtocolError::Malformed(format!(
                        "delta batch v3 declares {count} entries but carries {} payload bytes",
                        r.remaining()
                    )));
                }
                let mut entries = Vec::with_capacity(count as usize);
                let mut seal_unix_ns = 0u64;
                let mut writer_traces = Vec::new();
                for _ in 0..count {
                    let key = r.u64()?;
                    let kind = r.u8()?;
                    let len = r.u32()? as usize;
                    let delta = match kind {
                        delta_kind::FULL => SketchDelta::Full(r.bytes(len)?.to_vec()),
                        delta_kind::REGISTER_DIFF => {
                            SketchDelta::RegisterDiff(r.bytes(len)?.to_vec())
                        }
                        delta_kind::GLOBAL_DIFF => {
                            SketchDelta::GlobalDiff(r.bytes(len)?.to_vec())
                        }
                        delta_kind::TOMBSTONE => {
                            if len != 0 {
                                return Err(ProtocolError::Malformed(format!(
                                    "tombstone entry for key {key} declares a {len}-byte body"
                                )));
                            }
                            SketchDelta::Tombstone
                        }
                        delta_kind::SEAL_TS => {
                            // Batch metadata, not a delta: capture the
                            // timestamp and keep it out of `entries`.
                            if len != 8 {
                                return Err(ProtocolError::Malformed(format!(
                                    "seal timestamp entry declares a {len}-byte body (want 8)"
                                )));
                            }
                            let body: [u8; 8] = r.bytes(8)?.try_into().unwrap();
                            seal_unix_ns = u64::from_le_bytes(body);
                            continue;
                        }
                        delta_kind::TRACE_IDS => {
                            // Batch metadata like SEAL_TS: captured off
                            // to the side, never merged as a delta.
                            if len % 8 != 0 || len / 8 > MAX_WRITER_TRACES {
                                return Err(ProtocolError::Malformed(format!(
                                    "trace ids entry declares a {len}-byte body \
                                     (want a multiple of 8, at most {})",
                                    MAX_WRITER_TRACES * 8
                                )));
                            }
                            writer_traces.reserve(len / 8);
                            for _ in 0..len / 8 {
                                writer_traces.push(r.u64()?);
                            }
                            continue;
                        }
                        other => {
                            return Err(ProtocolError::Malformed(format!(
                                "unknown delta entry kind {other}"
                            )))
                        }
                    };
                    entries.push((key, delta));
                }
                Response::DeltaBatchV3 { seq, entries, seal_unix_ns, writer_traces }
            }
            opcodes::METRICS_TEXT => {
                let len = r.u32()? as usize;
                let text = String::from_utf8(r.bytes(len)?.to_vec()).map_err(|_| {
                    ProtocolError::Malformed("metrics exposition not utf-8".into())
                })?;
                Response::MetricsText(text)
            }
            opcodes::TRACE_EVENTS => {
                let version = r.u8()?;
                if version != TRACE_EVENTS_VERSION {
                    return Err(ProtocolError::Malformed(format!(
                        "trace events version {version} (want {TRACE_EVENTS_VERSION})"
                    )));
                }
                let count = r.u32()?;
                // Alloc guard: the declared count must fit the payload
                // (checked in u64 so a hostile count cannot wrap).
                if r.remaining() as u64 != count as u64 * TRACE_EVENT_WIRE_LEN as u64 {
                    return Err(ProtocolError::Malformed(format!(
                        "trace events declares {count} records but carries {} payload bytes",
                        r.remaining()
                    )));
                }
                let mut events = Vec::with_capacity(count as usize);
                for _ in 0..count {
                    events.push(TraceEvent {
                        ns: r.u64()?,
                        trace_id: r.u64()?,
                        payload: r.u64()?,
                        stage: r.u8()?,
                        kind: r.u8()?,
                    });
                }
                Response::TraceEvents { events }
            }
            opcodes::ERROR => {
                let code = r.u8()?;
                let code = ErrorCode::from_u8(code)
                    .ok_or_else(|| ProtocolError::Malformed(format!("error code {code}")))?;
                let len = r.u32()? as usize;
                let message = String::from_utf8(r.bytes(len)?.to_vec())
                    .map_err(|_| ProtocolError::Malformed("error message not utf-8".into()))?;
                Response::Error { code, message }
            }
            other => return Err(ProtocolError::BadOpcode(other)),
        };
        r.finish()?;
        Ok(resp)
    }
}

// ---------------------------------------------------------------------------
// Frame I/O
// ---------------------------------------------------------------------------

/// Validate a frame header, returning `(opcode, payload_len)`.
pub fn parse_header(header: &[u8; FRAME_HEADER_LEN]) -> Result<(u8, u32), ProtocolError> {
    if header[0..2] != MAGIC {
        return Err(ProtocolError::BadMagic([header[0], header[1]]));
    }
    if header[2] != PROTO_VERSION {
        return Err(ProtocolError::BadVersion(header[2]));
    }
    let len = u32::from_le_bytes(header[4..8].try_into().unwrap());
    if len > MAX_PAYLOAD {
        return Err(ProtocolError::Oversize(len));
    }
    Ok((header[3], len))
}

/// Blocking read of one raw frame: `(opcode, payload)`.
pub fn read_frame(r: &mut impl Read) -> Result<(u8, Vec<u8>), ProtocolError> {
    let mut header = [0u8; FRAME_HEADER_LEN];
    r.read_exact(&mut header)?;
    let (opcode, len) = parse_header(&header)?;
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)?;
    Ok((opcode, payload))
}

/// Blocking read + decode of one request frame.
pub fn read_request(r: &mut impl Read) -> Result<Request, ProtocolError> {
    let (opcode, payload) = read_frame(r)?;
    Request::decode(opcode, &payload)
}

/// Blocking read + decode of one response frame.
pub fn read_response(r: &mut impl Read) -> Result<Response, ProtocolError> {
    let (opcode, payload) = read_frame(r)?;
    Response::decode(opcode, &payload)
}

// ---------------------------------------------------------------------------
// Incremental frame codecs (the event loop's nonblocking I/O state machines)
// ---------------------------------------------------------------------------

/// Incremental, resumable frame *decoder*: feed it whatever bytes a
/// nonblocking read produced ([`FrameDecoder::extend`]), pull complete
/// frames out ([`FrameDecoder::next_frame`]) — the replacement for the
/// blocking `read_exact` pair in [`read_frame`]. A frame split across
/// any number of reads (down to one byte at a time) reassembles
/// byte-exactly; validation is as strict as the blocking path: the
/// header is checked as soon as its 8 bytes are in (bad magic/version
/// and oversize length fields fail *before* the payload arrives, so a
/// hostile length can never drive an allocation), and a framing error
/// is terminal — the caller answers once and drops the connection,
/// exactly the old server's split between decode errors (recoverable)
/// and framing errors (fatal).
///
/// The decoder also counts **resumed frames**: whenever a pull attempt
/// ends mid-frame (bytes buffered but no complete frame — the caller
/// goes back to the poller and waits), the next frame that *does*
/// complete is one that was suspended across reads. This feeds the
/// server's `partial_frames_resumed` stat (the slow-loris
/// observability knob).
#[derive(Debug, Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
    /// Consumed prefix of `buf` (compacted away periodically).
    pos: usize,
    /// The last [`FrameDecoder::next_frame`] returned `Ok(None)` with a
    /// partial frame buffered: the next completion counts as resumed.
    partial_pending: bool,
    resumed: u64,
}

impl FrameDecoder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one read's worth of bytes.
    pub fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet consumed as frames.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Drain the resumed-frame counter (frames completed since the last
    /// take after an earlier pull had left them suspended mid-frame).
    pub fn take_resumed(&mut self) -> u64 {
        std::mem::take(&mut self.resumed)
    }

    /// Whether a pull would make progress right now: a complete frame
    /// is buffered, or a framing error is waiting to be raised. (What
    /// distinguishes "requests still to serve" from "a dead partial
    /// tail" on a half-closed connection that will never read more.)
    pub fn has_work(&self) -> bool {
        if self.buffered() < FRAME_HEADER_LEN {
            return false;
        }
        let header: [u8; FRAME_HEADER_LEN] =
            self.buf[self.pos..self.pos + FRAME_HEADER_LEN].try_into().unwrap();
        match parse_header(&header) {
            Ok((_, len)) => self.buffered() >= FRAME_HEADER_LEN + len as usize,
            Err(_) => true,
        }
    }

    /// Pull the next complete frame, if the buffer holds one.
    /// `Ok(None)` = incomplete, feed more bytes. `Err` = the stream's
    /// framing is broken (bad magic/version, oversize length) and
    /// cannot resync — drop the connection after answering.
    pub fn next_frame(&mut self) -> Result<Option<(u8, Vec<u8>)>, ProtocolError> {
        if self.buffered() < FRAME_HEADER_LEN {
            return self.suspend();
        }
        let header: [u8; FRAME_HEADER_LEN] =
            self.buf[self.pos..self.pos + FRAME_HEADER_LEN].try_into().unwrap();
        let (opcode, len) = parse_header(&header)?;
        let total = FRAME_HEADER_LEN + len as usize;
        if self.buffered() < total {
            return self.suspend();
        }
        let payload = self.buf[self.pos + FRAME_HEADER_LEN..self.pos + total].to_vec();
        self.pos += total;
        if self.partial_pending {
            // This frame sat incomplete when an earlier pull gave up:
            // its bytes arrived across more than one read.
            self.partial_pending = false;
            self.resumed += 1;
        }
        self.compact();
        Ok(Some((opcode, payload)))
    }

    /// An incomplete pull: remember whether it left a partial frame
    /// behind (that frame, once completed, counts as resumed).
    fn suspend(&mut self) -> Result<Option<(u8, Vec<u8>)>, ProtocolError> {
        if self.buffered() > 0 {
            self.partial_pending = true;
        }
        self.compact();
        Ok(None)
    }

    /// Reclaim the consumed prefix once it is fully drained or large;
    /// amortized O(1) per byte either way. A drained buffer whose
    /// capacity ballooned (one `MAX_PAYLOAD`-sized frame would
    /// otherwise pin ~64 MiB for the connection's whole lifetime —
    /// ruinous at hundreds of resident connections) is released back
    /// to the allocator.
    fn compact(&mut self) {
        if self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
            if self.buf.capacity() > 256 * 1024 {
                self.buf = Vec::new();
            }
        } else if self.pos >= 64 * 1024 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
    }
}

/// Incremental frame *encoder*: an outbound queue of already-encoded
/// frames drained by nonblocking writes — the replacement for blocking
/// `write_all`. [`FrameEncoder::write_to`] pushes as many bytes as the
/// socket takes and remembers the partial-write offset, so a peer that
/// reads slowly (or not at all) costs buffered bytes, never a blocked
/// thread; the server flips `POLLOUT` interest on whenever
/// [`FrameEncoder::pending`] is nonzero and pauses reads past a
/// backpressure threshold.
#[derive(Debug, Default)]
pub struct FrameEncoder {
    queue: std::collections::VecDeque<Vec<u8>>,
    /// Bytes of `queue.front()` already written.
    front_written: usize,
    pending: usize,
}

impl FrameEncoder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Queue one complete encoded frame.
    pub fn push(&mut self, frame: Vec<u8>) {
        self.pending += frame.len();
        self.queue.push_back(frame);
    }

    /// Bytes queued but not yet accepted by the socket.
    pub fn pending(&self) -> usize {
        self.pending
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Write as much as `w` accepts right now. `Ok(true)` = fully
    /// drained; `Ok(false)` = the socket would block with bytes still
    /// queued (re-arm write interest); `Err` = the connection is gone.
    pub fn write_to<W: io::Write>(&mut self, w: &mut W) -> io::Result<bool> {
        while let Some(front) = self.queue.front() {
            match w.write(&front[self.front_written..]) {
                Ok(0) => {
                    return Err(io::Error::new(
                        io::ErrorKind::WriteZero,
                        "peer stopped accepting bytes",
                    ))
                }
                Ok(n) => {
                    self.front_written += n;
                    self.pending -= n;
                    if self.front_written == front.len() {
                        self.queue.pop_front();
                        self.front_written = 0;
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(false),
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        Ok(true)
    }

    /// Vectored drain for real sockets: gathers up to [`WRITEV_BATCH`]
    /// queued frames (front partial-write offset honored) into one
    /// `writev(2)`, so a pipelined burst of small replies costs one
    /// syscall instead of one `write` per frame. Same contract as
    /// [`Self::write_to`]: `Ok(true)` = fully drained; `Ok(false)` =
    /// the socket would block (or took a short write) with bytes still
    /// queued — re-arm write interest; `Err` = the connection is gone.
    #[cfg(unix)]
    pub fn write_vectored_to(&mut self, fd: std::os::unix::io::RawFd) -> io::Result<bool> {
        use std::os::raw::c_int;
        while !self.queue.is_empty() {
            let mut iov = [IoVec { base: std::ptr::null(), len: 0 }; WRITEV_BATCH];
            let mut cnt = 0usize;
            let mut offered = 0usize;
            for (i, frame) in self.queue.iter().enumerate() {
                if cnt == WRITEV_BATCH {
                    break;
                }
                let skip = if i == 0 { self.front_written } else { 0 };
                let slice = &frame[skip..];
                if slice.is_empty() {
                    continue;
                }
                iov[cnt] = IoVec { base: slice.as_ptr(), len: slice.len() };
                cnt += 1;
                offered += slice.len();
            }
            let rc = unsafe { writev(fd, iov.as_ptr(), cnt as c_int) };
            if rc < 0 {
                let e = io::Error::last_os_error();
                match e.kind() {
                    io::ErrorKind::WouldBlock => return Ok(false),
                    io::ErrorKind::Interrupted => continue,
                    _ => return Err(e),
                }
            }
            let written = rc as usize;
            if written == 0 && offered > 0 {
                return Err(io::Error::new(
                    io::ErrorKind::WriteZero,
                    "peer stopped accepting bytes",
                ));
            }
            self.pending -= written;
            // Advance the queue past the accepted bytes.
            let mut n = written;
            while n > 0 {
                let front_left = match self.queue.front() {
                    Some(f) => f.len() - self.front_written,
                    None => break,
                };
                if n >= front_left {
                    n -= front_left;
                    self.queue.pop_front();
                    self.front_written = 0;
                } else {
                    self.front_written += n;
                    n = 0;
                }
            }
            // A short write means the socket buffer filled mid-batch:
            // stop here instead of spinning into a guaranteed EAGAIN.
            if written < offered {
                return Ok(false);
            }
        }
        Ok(true)
    }
}

/// Max frames gathered into one `writev` call (well under every
/// platform's `IOV_MAX` of 1024).
#[cfg(unix)]
const WRITEV_BATCH: usize = 64;

/// `struct iovec` — identical layout on every unix libc. `base` is
/// `*const`: `writev` never writes through it; the C prototype's
/// non-const `void *` is ABI-identical.
#[cfg(unix)]
#[repr(C)]
#[derive(Clone, Copy)]
struct IoVec {
    base: *const u8,
    len: usize,
}

#[cfg(unix)]
extern "C" {
    fn writev(fd: std::os::raw::c_int, iov: *const IoVec, iovcnt: std::os::raw::c_int) -> isize;
}

/// Strict little-endian payload cursor.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8], ProtocolError> {
        if self.remaining() < n {
            return Err(ProtocolError::Malformed(format!(
                "need {n} more bytes, have {}",
                self.remaining()
            )));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8, ProtocolError> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, ProtocolError> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, ProtocolError> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    /// Reject trailing bytes — a frame that decodes but has leftovers is
    /// a framing bug on the peer, not something to paper over.
    fn finish(self) -> Result<(), ProtocolError> {
        if self.pos != self.buf.len() {
            return Err(ProtocolError::Malformed(format!(
                "{} trailing payload bytes",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn roundtrip_request(req: Request) {
        let bytes = req.encode();
        let mut cur = Cursor::new(bytes);
        let got = read_request(&mut cur).unwrap();
        assert_eq!(got, req);
        assert_eq!(cur.position() as usize, cur.get_ref().len(), "frame fully consumed");
    }

    fn roundtrip_response(resp: Response) {
        let bytes = resp.encode();
        let mut cur = Cursor::new(bytes);
        let got = read_response(&mut cur).unwrap();
        assert_eq!(got, resp);
    }

    #[test]
    fn every_request_roundtrips() {
        roundtrip_request(Request::Ping);
        roundtrip_request(Request::InsertBatch { key: 7, words: vec![] });
        roundtrip_request(Request::InsertBatch {
            key: u64::MAX,
            words: vec![0, 1, u32::MAX, 0xDEAD_BEEF],
        });
        roundtrip_request(Request::Estimate { key: 42 });
        roundtrip_request(Request::GlobalEstimate);
        roundtrip_request(Request::MergeSketch { key: 3, bytes: vec![1, 2, 3, 4, 5] });
        roundtrip_request(Request::Stats);
        roundtrip_request(Request::Evict(EvictPolicy::Key(9)));
        roundtrip_request(Request::Evict(EvictPolicy::Idle { max_age: 100 }));
        roundtrip_request(Request::Evict(EvictPolicy::Budget { max_memory_bytes: 1 << 30 }));
        roundtrip_request(Request::Evict(EvictPolicy::IdleWall { max_age_secs: 3_600 }));
        roundtrip_request(Request::Snapshot);
        roundtrip_request(Request::Subscribe { epoch: 0, cursor: 0, wire: DELTA_WIRE_V3 });
        roundtrip_request(Request::Subscribe {
            epoch: u64::MAX,
            cursor: u64::MAX,
            wire: DELTA_WIRE_V2,
        });
        roundtrip_request(Request::ReplicaAck { cursor: 12345 });
        roundtrip_request(Request::MetricsDump);
        roundtrip_request(Request::TraceDump);
        roundtrip_request(Request::Subscribe { epoch: 5, cursor: 6, wire: DELTA_WIRE_V4 });
    }

    #[test]
    fn legacy_16_byte_subscribe_decodes_as_wire_v2() {
        // A pre-wire-field subscriber ships only epoch + cursor; it
        // must decode as a v2 (full-sketch) subscriber, not an error.
        let mut payload = 7u64.to_le_bytes().to_vec();
        payload.extend_from_slice(&42u64.to_le_bytes());
        assert_eq!(
            Request::decode(opcodes::SUBSCRIBE, &payload).unwrap(),
            Request::Subscribe { epoch: 7, cursor: 42, wire: DELTA_WIRE_V2 }
        );
        // A wire generation below v2 does not exist.
        payload.push(1);
        assert!(matches!(
            Request::decode(opcodes::SUBSCRIBE, &payload),
            Err(ProtocolError::Malformed(_))
        ));
        // Trailing bytes past the wire field are still rejected.
        let mut fat = 7u64.to_le_bytes().to_vec();
        fat.extend_from_slice(&42u64.to_le_bytes());
        fat.push(DELTA_WIRE_V3);
        fat.push(0);
        assert!(matches!(
            Request::decode(opcodes::SUBSCRIBE, &fat),
            Err(ProtocolError::Malformed(_))
        ));
    }

    #[test]
    fn every_response_roundtrips() {
        roundtrip_response(Response::Pong);
        roundtrip_response(Response::Ingested { words: 12345 });
        roundtrip_response(Response::Estimate(None));
        roundtrip_response(Response::Estimate(Some(1234.5678)));
        roundtrip_response(Response::GlobalEstimate(Some(0.0)));
        roundtrip_response(Response::GlobalEstimate(None));
        roundtrip_response(Response::Merged);
        roundtrip_response(Response::Stats(StatsSummary {
            keys: 1,
            sparse_keys: 2,
            packed_keys: 6,
            dense_keys: 3,
            memory_bytes: 4,
            words: 5,
            estimator: 1,
        }));
        roundtrip_response(Response::Evicted { keys: 17 });
        roundtrip_response(Response::SnapshotDone { keys: 8, bytes: 4096 });
        roundtrip_response(Response::FullSync {
            epoch: 0xE9,
            cursor: 42,
            body: vec![9, 8, 7, 6],
        });
        roundtrip_response(Response::FullSync { epoch: 0, cursor: 0, body: vec![] });
        roundtrip_response(Response::DeltaBatch { seq: 0, entries: vec![] });
        roundtrip_response(Response::DeltaBatch {
            seq: 77,
            entries: vec![(1, vec![1, 2, 3]), (u64::MAX, vec![]), (9, vec![0; 64])],
        });
        roundtrip_response(Response::DeltaBatchV3 {
            seq: 0,
            entries: vec![],
            seal_unix_ns: 0,
            writer_traces: vec![],
        });
        roundtrip_response(Response::DeltaBatchV3 {
            seq: 91,
            entries: vec![
                (1, SketchDelta::Tombstone),
                (1, SketchDelta::Full(vec![7, 8, 9])),
                (2, SketchDelta::RegisterDiff(vec![1, 2, 3, 4, 5])),
                (u64::MAX, SketchDelta::Tombstone),
            ],
            seal_unix_ns: 0,
            writer_traces: vec![],
        });
        // The seal timestamp rides as a trailing metadata entry and
        // roundtrips without polluting `entries`.
        roundtrip_response(Response::DeltaBatchV3 {
            seq: 92,
            entries: vec![(1, SketchDelta::Full(vec![7]))],
            seal_unix_ns: 1_722_000_000_000_000_000,
            writer_traces: vec![],
        });
        // Writer trace IDs ride as a trailing metadata entry too (wire
        // v4), alone or alongside the seal timestamp.
        roundtrip_response(Response::DeltaBatchV3 {
            seq: 93,
            entries: vec![(1, SketchDelta::Full(vec![7]))],
            seal_unix_ns: 0,
            writer_traces: vec![0xAB, u64::MAX],
        });
        roundtrip_response(Response::DeltaBatchV3 {
            seq: 94,
            entries: vec![(2, SketchDelta::Tombstone)],
            seal_unix_ns: 1_722_000_000_000_000_001,
            writer_traces: (1..=MAX_WRITER_TRACES as u64).collect(),
        });
        roundtrip_response(Response::TraceEvents { events: vec![] });
        roundtrip_response(Response::TraceEvents {
            events: vec![
                TraceEvent { ns: 1, trace_id: 2, payload: 3, stage: 1, kind: 0 },
                TraceEvent {
                    ns: u64::MAX,
                    trace_id: u64::MAX,
                    payload: u64::MAX,
                    stage: 255,
                    kind: 255,
                },
            ],
        });
        roundtrip_response(Response::MetricsText(String::new()));
        roundtrip_response(Response::MetricsText(
            "# hll-metrics v1\nrpc_total{op=\"ping\"} 3\n".into(),
        ));
        roundtrip_response(Response::Error {
            code: ErrorCode::ConfigMismatch,
            message: "seed mismatch".into(),
        });
        roundtrip_response(Response::Error {
            code: ErrorCode::ReadOnly,
            message: "replica is read-only".into(),
        });
    }

    #[test]
    fn hostile_delta_batch_payloads_are_typed_errors() {
        let good = Response::DeltaBatch {
            seq: 9,
            entries: vec![(1, vec![1, 2, 3]), (2, vec![4])],
        }
        .encode();
        let payload = &good[FRAME_HEADER_LEN..];
        // The intact payload decodes.
        assert!(Response::decode(opcodes::DELTA_BATCH, payload).is_ok());
        // Truncation anywhere inside the entries is a typed error.
        for cut in [0usize, 8, 12, 13, 20, payload.len() - 1] {
            assert!(
                matches!(
                    Response::decode(opcodes::DELTA_BATCH, &payload[..cut]),
                    Err(ProtocolError::Malformed(_))
                ),
                "cut at {cut} must be Malformed"
            );
        }
        // Trailing bytes rejected.
        let mut padded = payload.to_vec();
        padded.push(0);
        assert!(matches!(
            Response::decode(opcodes::DELTA_BATCH, &padded),
            Err(ProtocolError::Malformed(_))
        ));
        // A count the payload cannot carry is rejected before allocation.
        let mut huge = 1u64.to_le_bytes().to_vec();
        huge.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            Response::decode(opcodes::DELTA_BATCH, &huge),
            Err(ProtocolError::Malformed(_))
        ));
        // An entry whose declared length overruns the payload is rejected.
        let mut overrun = 3u64.to_le_bytes().to_vec();
        overrun.extend_from_slice(&1u32.to_le_bytes()); // one entry
        overrun.extend_from_slice(&5u64.to_le_bytes()); // key
        overrun.extend_from_slice(&100u32.to_le_bytes()); // claims 100 bytes
        overrun.extend_from_slice(&[1, 2, 3]); // carries 3
        assert!(matches!(
            Response::decode(opcodes::DELTA_BATCH, &overrun),
            Err(ProtocolError::Malformed(_))
        ));
        // FULL_SYNC with a short body is rejected too.
        let mut fs = 7u64.to_le_bytes().to_vec(); // epoch
        fs.extend_from_slice(&1u64.to_le_bytes()); // cursor
        fs.extend_from_slice(&50u32.to_le_bytes()); // claims 50 body bytes
        fs.extend_from_slice(&[0; 10]); // carries 10
        assert!(matches!(
            Response::decode(opcodes::FULL_SYNC, &fs),
            Err(ProtocolError::Malformed(_))
        ));
    }

    #[test]
    fn hostile_delta_batch_v3_payloads_are_typed_errors() {
        let good = Response::DeltaBatchV3 {
            seq: 4,
            entries: vec![
                (1, SketchDelta::Full(vec![1, 2, 3])),
                (2, SketchDelta::Tombstone),
                (3, SketchDelta::RegisterDiff(vec![9])),
            ],
            seal_unix_ns: 0,
            writer_traces: vec![],
        }
        .encode();
        let payload = &good[FRAME_HEADER_LEN..];
        assert!(Response::decode(opcodes::DELTA_BATCH_V3, payload).is_ok());
        // Truncation anywhere inside the entries is a typed error.
        for cut in [0usize, 8, 12, 13, 21, 25, payload.len() - 1] {
            assert!(
                matches!(
                    Response::decode(opcodes::DELTA_BATCH_V3, &payload[..cut]),
                    Err(ProtocolError::Malformed(_))
                ),
                "cut at {cut} must be Malformed"
            );
        }
        // Trailing bytes rejected.
        let mut padded = payload.to_vec();
        padded.push(0);
        assert!(matches!(
            Response::decode(opcodes::DELTA_BATCH_V3, &padded),
            Err(ProtocolError::Malformed(_))
        ));
        // A count the payload cannot carry is rejected before allocation.
        let mut huge = 1u64.to_le_bytes().to_vec();
        huge.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            Response::decode(opcodes::DELTA_BATCH_V3, &huge),
            Err(ProtocolError::Malformed(_))
        ));
        // An unknown entry kind is rejected.
        let mut bad_kind = 9u64.to_le_bytes().to_vec(); // seq
        bad_kind.extend_from_slice(&1u32.to_le_bytes()); // one entry
        bad_kind.extend_from_slice(&5u64.to_le_bytes()); // key
        bad_kind.push(7); // kind 7 does not exist
        bad_kind.extend_from_slice(&0u32.to_le_bytes());
        assert!(matches!(
            Response::decode(opcodes::DELTA_BATCH_V3, &bad_kind),
            Err(ProtocolError::Malformed(_))
        ));
        // A tombstone carrying a body is rejected.
        let mut fat_tomb = 9u64.to_le_bytes().to_vec();
        fat_tomb.extend_from_slice(&1u32.to_le_bytes());
        fat_tomb.extend_from_slice(&5u64.to_le_bytes());
        fat_tomb.push(delta_kind::TOMBSTONE);
        fat_tomb.extend_from_slice(&3u32.to_le_bytes());
        fat_tomb.extend_from_slice(&[1, 2, 3]);
        assert!(matches!(
            Response::decode(opcodes::DELTA_BATCH_V3, &fat_tomb),
            Err(ProtocolError::Malformed(_))
        ));
        // A body length overrunning the payload is rejected.
        let mut overrun = 9u64.to_le_bytes().to_vec();
        overrun.extend_from_slice(&1u32.to_le_bytes());
        overrun.extend_from_slice(&5u64.to_le_bytes());
        overrun.push(delta_kind::FULL);
        overrun.extend_from_slice(&100u32.to_le_bytes()); // claims 100 bytes
        overrun.extend_from_slice(&[1, 2, 3]); // carries 3
        assert!(matches!(
            Response::decode(opcodes::DELTA_BATCH_V3, &overrun),
            Err(ProtocolError::Malformed(_))
        ));
    }

    #[test]
    fn duplicate_and_tombstone_then_diff_entries_decode_in_order() {
        // Entry-level duplicates and tombstone-then-diff sequences for
        // one key are *valid wire* — apply-order semantics resolve them
        // (the follower applies entries sequentially). The decoder must
        // hand them through byte-exactly, in order, without panicking.
        let entries = vec![
            (5, SketchDelta::Full(vec![1, 1])),
            (5, SketchDelta::Full(vec![1, 1])), // duplicate
            (5, SketchDelta::Tombstone),
            (5, SketchDelta::RegisterDiff(vec![2, 2])), // diff right after a tombstone
            (5, SketchDelta::Tombstone),                // and dead again
        ];
        let frame = Response::DeltaBatchV3 {
            seq: 8,
            entries: entries.clone(),
            seal_unix_ns: 0,
            writer_traces: vec![],
        }
        .encode();
        match Response::decode(opcodes::DELTA_BATCH_V3, &frame[FRAME_HEADER_LEN..]).unwrap() {
            Response::DeltaBatchV3 { seq, entries: got, seal_unix_ns, writer_traces } => {
                assert_eq!(seq, 8);
                assert_eq!(got, entries, "order and duplicates must survive the wire");
                assert_eq!(seal_unix_ns, 0);
                assert!(writer_traces.is_empty());
            }
            other => panic!("expected DeltaBatchV3, got {other:?}"),
        }
    }

    #[test]
    fn seal_timestamp_and_metrics_text_hostile_payloads_are_typed_errors() {
        // A seal entry whose body is not exactly 8 bytes is rejected.
        let mut bad_seal = 9u64.to_le_bytes().to_vec(); // seq
        bad_seal.extend_from_slice(&1u32.to_le_bytes()); // one entry
        bad_seal.extend_from_slice(&0u64.to_le_bytes()); // key 0
        bad_seal.push(delta_kind::SEAL_TS);
        bad_seal.extend_from_slice(&4u32.to_le_bytes()); // 4-byte body
        bad_seal.extend_from_slice(&[1, 2, 3, 4]);
        assert!(matches!(
            Response::decode(opcodes::DELTA_BATCH_V3, &bad_seal),
            Err(ProtocolError::Malformed(_))
        ));
        // METRICS_TEXT with non-utf-8 bytes is a typed error, not a panic.
        let mut bad_text = 4u32.to_le_bytes().to_vec();
        bad_text.extend_from_slice(&[0xFF, 0xFE, 0x80, 0x00]);
        assert!(matches!(
            Response::decode(opcodes::METRICS_TEXT, &bad_text),
            Err(ProtocolError::Malformed(_))
        ));
        // A declared length overrunning the payload is rejected.
        let mut overrun = 100u32.to_le_bytes().to_vec();
        overrun.extend_from_slice(b"short");
        assert!(matches!(
            Response::decode(opcodes::METRICS_TEXT, &overrun),
            Err(ProtocolError::Malformed(_))
        ));
        // Trailing bytes past the declared text are rejected.
        let good = Response::MetricsText("ok".into()).encode();
        let mut padded = good[FRAME_HEADER_LEN..].to_vec();
        padded.push(0);
        assert!(matches!(
            Response::decode(opcodes::METRICS_TEXT, &padded),
            Err(ProtocolError::Malformed(_))
        ));
    }

    #[test]
    fn trace_ids_and_trace_events_hostile_payloads_are_typed_errors() {
        let trace_ids_payload = |body: &[u8]| {
            let mut p = 9u64.to_le_bytes().to_vec(); // seq
            p.extend_from_slice(&1u32.to_le_bytes()); // one entry
            p.extend_from_slice(&0u64.to_le_bytes()); // key 0
            p.push(delta_kind::TRACE_IDS);
            p.extend_from_slice(&(body.len() as u32).to_le_bytes());
            p.extend_from_slice(body);
            p
        };
        // A body that is not a multiple of 8 is rejected.
        assert!(matches!(
            Response::decode(opcodes::DELTA_BATCH_V3, &trace_ids_payload(&[1, 2, 3])),
            Err(ProtocolError::Malformed(_))
        ));
        // More IDs than the cap is rejected (hostile length guard).
        let fat = vec![0u8; (MAX_WRITER_TRACES + 1) * 8];
        assert!(matches!(
            Response::decode(opcodes::DELTA_BATCH_V3, &trace_ids_payload(&fat)),
            Err(ProtocolError::Malformed(_))
        ));
        // Exactly the cap decodes.
        let max = vec![7u8; MAX_WRITER_TRACES * 8];
        assert!(Response::decode(opcodes::DELTA_BATCH_V3, &trace_ids_payload(&max)).is_ok());
        // TRACE_EVENTS: unknown version is rejected.
        let mut bad_version = vec![2u8];
        bad_version.extend_from_slice(&0u32.to_le_bytes());
        assert!(matches!(
            Response::decode(opcodes::TRACE_EVENTS, &bad_version),
            Err(ProtocolError::Malformed(_))
        ));
        // A count disagreeing with the payload size is rejected before
        // allocation, in both directions.
        for (count, carry) in [(u32::MAX, 0usize), (2, TRACE_EVENT_WIRE_LEN), (1, 0)] {
            let mut p = vec![TRACE_EVENTS_VERSION];
            p.extend_from_slice(&count.to_le_bytes());
            p.extend_from_slice(&vec![0u8; carry]);
            assert!(
                matches!(
                    Response::decode(opcodes::TRACE_EVENTS, &p),
                    Err(ProtocolError::Malformed(_))
                ),
                "count {count} with {carry} body bytes must be Malformed"
            );
        }
        // Trailing bytes rejected.
        let good = Response::TraceEvents {
            events: vec![TraceEvent { ns: 1, trace_id: 2, payload: 3, stage: 0, kind: 0 }],
        }
        .encode();
        let mut padded = good[FRAME_HEADER_LEN..].to_vec();
        padded.push(0);
        assert!(matches!(
            Response::decode(opcodes::TRACE_EVENTS, &padded),
            Err(ProtocolError::Malformed(_))
        ));
    }

    #[test]
    fn traced_insert_batch_peels_cleanly_and_stays_strict_for_old_decoders() {
        let trace_id = 0xABCD_EF01_2345_6789u64;
        for words in [vec![], vec![10u32, 20, 30]] {
            let frame = encode_insert_batch_traced(42, &words, trace_id);
            let payload = &frame[FRAME_HEADER_LEN..];
            // A pre-tracing decoder (strict length check) rejects the
            // longer payload — which is why clients must probe first.
            assert!(matches!(
                Request::decode(opcodes::INSERT_BATCH, payload),
                Err(ProtocolError::Malformed(_))
            ));
            // The server-side peel recovers the body and the ID...
            let (body, id) = split_trace_ctx(opcodes::INSERT_BATCH, payload);
            assert_eq!(id, Some(trace_id));
            assert_eq!(
                Request::decode(opcodes::INSERT_BATCH, body).unwrap(),
                Request::InsertBatch { key: 42, words: words.clone() }
            );
            // ...and an untraced frame passes through untouched.
            let plain = encode_insert_batch(42, &words);
            let (body, id) = split_trace_ctx(opcodes::INSERT_BATCH, &plain[FRAME_HEADER_LEN..]);
            assert_eq!(id, None);
            assert_eq!(body, &plain[FRAME_HEADER_LEN..]);
        }
        // 16 trailing garbage bytes (sampled flag clear) are NOT peeled:
        // strict decode rejects them exactly as before tracing existed.
        let mut garbage = encode_insert_batch(7, &[1, 2])[FRAME_HEADER_LEN..].to_vec();
        garbage.extend_from_slice(&[0u8; TRACE_CTX_LEN]);
        let (body, id) = split_trace_ctx(opcodes::INSERT_BATCH, &garbage);
        assert_eq!(id, None);
        assert_eq!(body.len(), garbage.len(), "garbage trailer must not be peeled");
        assert!(matches!(
            Request::decode(opcodes::INSERT_BATCH, &garbage),
            Err(ProtocolError::Malformed(_))
        ));
        // Other opcodes never peel, even with a plausible trailer.
        let mut est = 5u64.to_le_bytes().to_vec();
        est.extend_from_slice(&crate::obs::trace::encode_trace_ctx(trace_id));
        let (_, id) = split_trace_ctx(opcodes::ESTIMATE, &est);
        assert_eq!(id, None);
    }

    #[test]
    fn v3_and_v4_delta_encodings_differ_only_by_the_trace_entry() {
        let entries = vec![(1, SketchDelta::Full(vec![1, 2, 3]))];
        // No traces: v4 bytes are exactly v3 bytes.
        assert_eq!(
            encode_delta_batch_v4(5, &entries, 99, &[]),
            encode_delta_batch_v3(5, &entries, 99),
        );
        // With traces: the v4 frame decodes back with the IDs; the v3
        // rendering of the same batch stays free of kind-5 entries (a
        // v3 subscriber's strict decoder accepts it).
        let v4 = encode_delta_batch_v4(5, &entries, 99, &[11, 22]);
        match Response::decode(opcodes::DELTA_BATCH_V3, &v4[FRAME_HEADER_LEN..]).unwrap() {
            Response::DeltaBatchV3 { entries: got, seal_unix_ns, writer_traces, .. } => {
                assert_eq!(got, entries);
                assert_eq!(seal_unix_ns, 99);
                assert_eq!(writer_traces, vec![11, 22]);
            }
            other => panic!("expected DeltaBatchV3, got {other:?}"),
        }
        let v3 = encode_delta_batch_v3(5, &entries, 99);
        match Response::decode(opcodes::DELTA_BATCH_V3, &v3[FRAME_HEADER_LEN..]).unwrap() {
            Response::DeltaBatchV3 { writer_traces, .. } => assert!(writer_traces.is_empty()),
            other => panic!("expected DeltaBatchV3, got {other:?}"),
        }
    }

    #[test]
    fn bad_magic_version_opcode_oversize() {
        let good = Request::Ping.encode();
        assert!(matches!(
            parse_header(good[..8].try_into().unwrap()),
            Ok((opcodes::PING, 0))
        ));

        let mut bad_magic = good.clone();
        bad_magic[0] = b'X';
        assert!(matches!(
            read_request(&mut Cursor::new(bad_magic)),
            Err(ProtocolError::BadMagic(_))
        ));

        let mut bad_version = good.clone();
        bad_version[2] = 99;
        assert!(matches!(
            read_request(&mut Cursor::new(bad_version)),
            Err(ProtocolError::BadVersion(99))
        ));

        let mut bad_opcode = good.clone();
        bad_opcode[3] = 0x7F;
        assert!(matches!(
            read_request(&mut Cursor::new(bad_opcode)),
            Err(ProtocolError::BadOpcode(0x7F))
        ));

        let mut oversize = good;
        oversize[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            read_request(&mut Cursor::new(oversize)),
            Err(ProtocolError::Oversize(_))
        ));
    }

    #[test]
    fn truncated_frames_are_io_errors() {
        // Header cut short.
        let full = Request::Estimate { key: 5 }.encode();
        for cut in [0usize, 3, 7, 9, full.len() - 1] {
            let err = read_request(&mut Cursor::new(full[..cut].to_vec())).unwrap_err();
            assert!(matches!(err, ProtocolError::Io(_)), "cut at {cut}: {err}");
        }
    }

    #[test]
    fn strict_payload_decoding() {
        // Trailing bytes rejected.
        let mut payload = 5u64.to_le_bytes().to_vec();
        payload.push(0);
        assert!(matches!(
            Request::decode(opcodes::ESTIMATE, &payload),
            Err(ProtocolError::Malformed(_))
        ));
        // Word count disagreeing with payload size rejected.
        let mut bad = 1u64.to_le_bytes().to_vec();
        bad.extend_from_slice(&10u32.to_le_bytes()); // claims 10 words
        bad.extend_from_slice(&0u32.to_le_bytes()); // carries 1
        assert!(matches!(
            Request::decode(opcodes::INSERT_BATCH, &bad),
            Err(ProtocolError::Malformed(_))
        ));
        // Unknown evict policy rejected.
        let mut evict = vec![9u8];
        evict.extend_from_slice(&0u64.to_le_bytes());
        assert!(matches!(
            Request::decode(opcodes::EVICT, &evict),
            Err(ProtocolError::Malformed(_))
        ));
        // Unknown error code rejected.
        let mut err_payload = vec![200u8];
        err_payload.extend_from_slice(&0u32.to_le_bytes());
        assert!(matches!(
            Response::decode(opcodes::ERROR, &err_payload),
            Err(ProtocolError::Malformed(_))
        ));
        // Bad presence flag rejected.
        let mut est = vec![7u8];
        est.extend_from_slice(&0u64.to_le_bytes());
        assert!(matches!(
            Response::decode(opcodes::ESTIMATE_REPLY, &est),
            Err(ProtocolError::Malformed(_))
        ));
    }

    #[test]
    fn pipelined_frames_decode_in_sequence() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&encode_insert_batch(1, &[10, 20]));
        wire.extend_from_slice(&encode_insert_batch(2, &[30]));
        wire.extend_from_slice(&Request::Stats.encode());
        let mut cur = Cursor::new(wire);
        assert_eq!(
            read_request(&mut cur).unwrap(),
            Request::InsertBatch { key: 1, words: vec![10, 20] }
        );
        assert_eq!(
            read_request(&mut cur).unwrap(),
            Request::InsertBatch { key: 2, words: vec![30] }
        );
        assert_eq!(read_request(&mut cur).unwrap(), Request::Stats);
    }

    #[test]
    fn frame_decoder_reassembles_at_every_split_point() {
        // Three pipelined frames, split at every possible boundary: the
        // incremental decoder must yield exactly what the blocking
        // reader yields, regardless of where the reads land.
        let mut wire = Vec::new();
        wire.extend_from_slice(&encode_insert_batch(1, &[10, 20]));
        wire.extend_from_slice(&Request::Stats.encode());
        wire.extend_from_slice(&encode_insert_batch(2, &[30]));
        let expect = vec![
            Request::InsertBatch { key: 1, words: vec![10, 20] },
            Request::Stats,
            Request::InsertBatch { key: 2, words: vec![30] },
        ];
        for cut in 0..=wire.len() {
            let mut dec = FrameDecoder::new();
            let mut got = Vec::new();
            for chunk in [&wire[..cut], &wire[cut..]] {
                dec.extend(chunk);
                while let Some((op, payload)) = dec.next_frame().unwrap() {
                    got.push(Request::decode(op, &payload).unwrap());
                }
            }
            assert_eq!(got, expect, "split at {cut}");
            assert_eq!(dec.buffered(), 0, "split at {cut} left residue");
        }
    }

    #[test]
    fn frame_decoder_counts_resumed_frames() {
        let frame = encode_insert_batch(7, &[1, 2, 3]);
        // Whole frame in one read: nothing resumed.
        let mut dec = FrameDecoder::new();
        dec.extend(&frame);
        assert!(dec.next_frame().unwrap().is_some());
        assert_eq!(dec.take_resumed(), 0);
        // One byte per read, pulled between reads like the event loop
        // does (slow loris): exactly one resumed frame.
        let mut dec = FrameDecoder::new();
        for &b in &frame {
            assert!(dec.next_frame().unwrap().is_none(), "no frame before the last byte");
            dec.extend(&[b]);
        }
        assert!(dec.next_frame().unwrap().is_some());
        assert_eq!(dec.take_resumed(), 1);
        assert_eq!(dec.take_resumed(), 0, "counter must drain");
        // A pipelined pair split mid-second-frame, pulled between the
        // two reads: only the split frame counts.
        let mut wire = frame.clone();
        wire.extend_from_slice(&Request::Ping.encode());
        let cut = frame.len() + 3;
        let mut dec = FrameDecoder::new();
        dec.extend(&wire[..cut]);
        let mut frames = 0;
        while dec.next_frame().unwrap().is_some() {
            frames += 1;
        }
        assert_eq!(frames, 1, "only the first frame is complete at the cut");
        dec.extend(&wire[cut..]);
        while dec.next_frame().unwrap().is_some() {
            frames += 1;
        }
        assert_eq!(frames, 2);
        assert_eq!(dec.take_resumed(), 1, "only the split frame counts as resumed");
    }

    #[test]
    fn frame_decoder_rejects_hostile_headers_before_payload() {
        // Bad magic fails as soon as the header is in.
        let mut dec = FrameDecoder::new();
        dec.extend(b"XX\x01\x01\x00\x00\x00\x00");
        assert!(matches!(dec.next_frame(), Err(ProtocolError::BadMagic(_))));
        // Oversize length fails with no payload byte ever buffered.
        let mut dec = FrameDecoder::new();
        let mut hdr = Vec::new();
        hdr.extend_from_slice(&MAGIC);
        hdr.push(PROTO_VERSION);
        hdr.push(opcodes::PING);
        hdr.extend_from_slice(&u32::MAX.to_le_bytes());
        dec.extend(&hdr);
        assert!(matches!(dec.next_frame(), Err(ProtocolError::Oversize(_))));
        // Bad version, trickled byte-at-a-time, still fails at byte 8.
        let mut dec = FrameDecoder::new();
        for &b in b"HL\x63\x01\x00\x00\x00\x00" {
            dec.extend(&[b]);
        }
        assert!(matches!(dec.next_frame(), Err(ProtocolError::BadVersion(0x63))));
        // An incomplete header is just "feed me more".
        let mut dec = FrameDecoder::new();
        dec.extend(b"HL\x01");
        assert!(matches!(dec.next_frame(), Ok(None)));
    }

    /// A sink that accepts at most `cap` bytes per write call, then
    /// reports WouldBlock — a nonblocking socket with a tiny buffer.
    struct Throttle {
        out: Vec<u8>,
        cap: usize,
        budget: usize,
    }

    impl std::io::Write for Throttle {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            if self.budget == 0 {
                return Err(std::io::Error::new(std::io::ErrorKind::WouldBlock, "full"));
            }
            let n = buf.len().min(self.cap).min(self.budget);
            self.out.extend_from_slice(&buf[..n]);
            self.budget -= n;
            Ok(n)
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn frame_encoder_resumes_partial_writes_byte_exactly() {
        let frames =
            [Request::Ping.encode(), encode_insert_batch(9, &[1, 2, 3, 4]), Request::Stats.encode()];
        let want: Vec<u8> = frames.iter().flatten().copied().collect();
        let mut enc = FrameEncoder::new();
        for f in &frames {
            enc.push(f.clone());
        }
        assert_eq!(enc.pending(), want.len());
        // Drain through a sink that takes 3 bytes per call and blocks
        // every 7: the encoder must resume mid-frame without loss,
        // duplication or reordering.
        let mut sink = Throttle { out: Vec::new(), cap: 3, budget: 7 };
        while !enc.is_empty() {
            match enc.write_to(&mut sink).unwrap() {
                true => break,
                false => sink.budget = 7, // socket drained; writable again
            }
        }
        assert!(enc.is_empty());
        assert_eq!(enc.pending(), 0);
        assert_eq!(sink.out, want);
    }

    /// The vectored drain must deliver the same byte stream as the
    /// scalar one: many small frames (spanning several `writev`
    /// batches) plus one large frame, driven against a real socket
    /// with a finite buffer so short writes and `WouldBlock` both
    /// occur.
    #[cfg(unix)]
    #[test]
    fn frame_encoder_vectored_drain_is_byte_exact() {
        use std::io::Read;
        use std::os::unix::io::AsRawFd;
        use std::os::unix::net::UnixStream;
        let (a, b) = UnixStream::pair().unwrap();
        a.set_nonblocking(true).unwrap();
        b.set_nonblocking(true).unwrap();
        let mut enc = FrameEncoder::new();
        let mut want: Vec<u8> = Vec::new();
        for i in 0..200u32 {
            let f = encode_insert_batch(i as u64, &[i, i + 1, i + 2]);
            want.extend_from_slice(&f);
            enc.push(f);
        }
        let words: Vec<u32> = vec![42; 60_000];
        let big = encode_insert_batch(7, &words);
        want.extend_from_slice(&big);
        enc.push(big);
        let mut got = Vec::new();
        let mut buf = [0u8; 16384];
        loop {
            let drained = enc.write_vectored_to(a.as_raw_fd()).unwrap();
            // Pull whatever landed so the socket buffer frees up.
            loop {
                match (&b).read(&mut buf) {
                    Ok(0) => break,
                    Ok(n) => got.extend_from_slice(&buf[..n]),
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) => panic!("read: {e}"),
                }
            }
            if drained {
                break;
            }
        }
        assert!(enc.is_empty());
        assert_eq!(enc.pending(), 0);
        assert_eq!(got, want);
    }

    #[test]
    fn global_diff_entries_roundtrip_on_the_v3_wire() {
        let entries = vec![
            (0, SketchDelta::GlobalDiff(vec![1, 2, 3, 4, 5])),
            (5, SketchDelta::Tombstone),
        ];
        let frame = Response::DeltaBatchV3 {
            seq: 3,
            entries: entries.clone(),
            seal_unix_ns: 7_777,
            writer_traces: vec![0xF00D],
        }
        .encode();
        match Response::decode(opcodes::DELTA_BATCH_V3, &frame[FRAME_HEADER_LEN..]).unwrap() {
            Response::DeltaBatchV3 { seq, entries: got, seal_unix_ns, writer_traces } => {
                assert_eq!(seq, 3);
                assert_eq!(got, entries);
                assert_eq!(seal_unix_ns, 7_777, "seal timestamp must survive the wire");
                assert_eq!(writer_traces, vec![0xF00D], "trace ids must survive the wire");
            }
            other => panic!("expected DeltaBatchV3, got {other:?}"),
        }
    }

    #[test]
    fn stats_summary_from_registry_stats() {
        use crate::hll::EstimatorKind;
        use crate::registry::ShardStats;
        let stats = RegistryStats {
            shards: vec![ShardStats {
                keys: 3,
                sparse_keys: 1,
                packed_keys: 1,
                dense_keys: 1,
                memory_bytes: 640,
                words: 99,
            }],
            estimator: EstimatorKind::Legacy,
        };
        let s = StatsSummary::from(&stats);
        assert_eq!(s.keys, 3);
        assert_eq!(s.sparse_keys, 1);
        assert_eq!(s.packed_keys, 1);
        assert_eq!(s.dense_keys, 1);
        assert_eq!(s.memory_bytes, 640);
        assert_eq!(s.words, 99);
        assert_eq!(s.estimator, EstimatorKind::Legacy.as_wire_byte());
    }
}
