//! Network serving subsystem: the production front door of the
//! multi-tenant sketch registry.
//!
//! The paper's headline scenario is HLL consuming streams "coming from
//! high-speed networks"; [`crate::net`] models that NIC deployment as a
//! discrete-event *simulation* (Table IV), while this module is the
//! *real* serving path — actual loopback/LAN sockets in front of a
//! shared [`crate::registry::SketchRegistry`]:
//!
//! * [`protocol`] — the length-prefixed, versioned binary frame protocol
//!   (`InsertBatch`, `Estimate`, `GlobalEstimate`, `MergeSketch` using
//!   the seed-carrying sketch wire format v2, `Stats`, `Evict` with
//!   key/TTL/wall-TTL/budget policies, `Snapshot`, `Ping`,
//!   `MetricsDump` — the [`crate::obs::MetricsRegistry`] exposition
//!   scraped over the wire — `TraceDump` — the
//!   [`crate::obs::recorder`] flight-recorder snapshot as a versioned
//!   binary event frame — plus the replication frames `Subscribe`/`ReplicaAck`/`FullSync`/
//!   `DeltaBatch` — wire-v3 typed delta entries: register diffs,
//!   full sketches, eviction tombstones, global-union diffs; wire-v4
//!   adds last-writer trace ids riding each sealed batch), with
//!   typed error frames, strict panic-free decoding, and the
//!   incremental [`protocol::FrameDecoder`]/[`protocol::FrameEncoder`]
//!   state machines that reassemble frames across partial nonblocking
//!   reads and writes;
//! * [`reactor`] — the readiness substrate: a three-method `Poller`
//!   surface over interchangeable kernel backends
//!   ([`reactor::PollerBackend`]: `poll(2)` everywhere, `epoll(7)` on
//!   Linux with persistent level-triggered interest mutated only on
//!   state change, a `kqueue` selection stub for BSD/macOS; default =
//!   best available, env override `HLL_POLLER`), plus self-pipe
//!   [`reactor::Waker`]s for cross-thread wakeups — dependency-free;
//! * [`reuseport`] — raw-syscall `SO_REUSEPORT` listener groups: one
//!   listening socket per event loop on a shared port, so the kernel
//!   shards accepts across loops instead of funneling them through
//!   loop 0 (Linux; other platforms fall back to routed accepts);
//! * [`server`] — the event-driven server: one (configurably N)
//!   nonblocking loop thread multiplexing every connection through
//!   per-connection state machines (reading → dispatching → writing →
//!   subscribed), vectored `writev` reply draining, write backpressure
//!   via interest flipping, idle timeouts and a connection cap, a
//!   small worker pool taking blocking work (`Snapshot` RPC, full-sync
//!   image serialization) off the loops, graceful shutdown that drains
//!   the pollers, an optional background maintenance sweeper
//!   ([`SweeperConfig`]: timer-driven TTL / wall-clock-TTL / budget
//!   eviction), optional read-only replica mode, per-opcode latency /
//!   payload histograms and per-loop + per-backend event-loop tick
//!   profiles feeding the process-wide metrics registry (plus
//!   rate-limited slow-request WARN tracing, threshold via
//!   `HLL_SLOW_REQ_MS`), and — with [`ServerConfig::replication`] — a
//!   replication primary role (capture thread + `SUBSCRIBE` streams,
//!   see [`crate::replica`]);
//! * [`client`] — a blocking [`SketchClient`] with batch pipelining
//!   (write a flight of ingest frames, then read the replies — one
//!   round trip per flight), optional typed socket timeouts, and
//!   opt-in request tracing ([`SketchClient::negotiate_tracing`]
//!   probes the server, after which ingest frames carry a 16-byte
//!   trace context that threads client → decode → dispatch → shard
//!   ingest → replication seal → follower apply);
//! * [`snapshot`] — checksummed full-registry snapshot files (format
//!   v2: per-key records plus the global-union record, v1 read-compat)
//!   and the restore paths, so a restarted server resumes with
//!   identical estimates — `GlobalEstimate` included — and sketches
//!   ship across nodes.
//!
//! Remote ingest is bit-exact with in-process ingest: the server feeds
//! the same [`crate::registry::SketchRegistry::ingest`] path, so a
//! `SketchClient` and a local thread produce identical register files
//! for the same words (asserted over real sockets by
//! `rust/tests/server_e2e.rs`).
//!
//! ```no_run
//! use std::sync::Arc;
//! use hll_fpga::registry::{RegistryConfig, SketchRegistry};
//! use hll_fpga::server::{ServerConfig, SketchClient, SketchServer};
//!
//! let registry = SketchRegistry::shared(RegistryConfig::default()).unwrap();
//! let server =
//!     SketchServer::start("127.0.0.1:0", registry, ServerConfig::default()).unwrap();
//! let mut client = SketchClient::connect(server.local_addr()).unwrap();
//! client.insert_batch(42, &[1, 2, 3, 2]).unwrap();
//! assert!(client.estimate(42).unwrap().is_some());
//! server.shutdown();
//! ```

pub mod client;
pub mod protocol;
pub mod reactor;
pub mod reuseport;
pub mod server;
pub mod snapshot;

pub use client::{ClientError, SketchClient};
pub use protocol::{
    ErrorCode, EvictPolicy, FrameDecoder, FrameEncoder, ProtocolError, Request, Response,
    StatsSummary, MAX_PAYLOAD, PROTO_VERSION,
};
pub use reactor::PollerBackend;
pub use server::{ServerConfig, ServerStatsSnapshot, SketchServer, SweeperConfig};
pub use snapshot::{
    decode_snapshot_bytes, read_snapshot, read_snapshot_contents, replace_from_bytes,
    restore_from_bytes, restore_registry, snapshot_to_vec, write_snapshot, SnapshotContents,
    SnapshotError, SnapshotSummary, SNAPSHOT_MAGIC, SNAPSHOT_MAGIC_V1, SNAPSHOT_VERSION,
    SNAPSHOT_VERSION_V1,
};
