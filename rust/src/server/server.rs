//! The TCP serving front-end: a multi-threaded
//! [`std::net::TcpListener`] server that owns a shared
//! [`SketchRegistry`] and speaks the [`super::protocol`] frame protocol.
//!
//! One thread accepts; each connection gets a dedicated thread (the
//! blocking analogue of the paper's per-port NIC pipelines). The accept
//! loop and every connection read poll a shared stop flag on a short
//! interval, so [`SketchServer::shutdown`] (or drop) stops accepting
//! and joins every connection thread within one poll tick — a graceful
//! shutdown with no detached threads left touching the registry.
//!
//! Malformed frames are answered with typed `ERROR` frames where the
//! stream is still in sync (decode errors), and the connection is
//! dropped where it cannot be (framing errors) — the server never
//! panics on hostile input.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::protocol::{
    parse_header, ErrorCode, EvictPolicy, Request, Response, StatsSummary, FRAME_HEADER_LEN,
};
use super::snapshot;
use crate::hll::{HllSketch, SketchError};
use crate::registry::SketchRegistry;

/// Ingest frames between server-driven
/// [`SketchRegistry::enforce_budget`] sweeps on a registry configured
/// with [`crate::registry::RegistryConfig::max_memory_bytes`]. The
/// sweep's accounting walk is O(keys), so it is amortized rather than
/// run per batch; the budget is a soft target either way.
const BUDGET_ENFORCE_EVERY: u64 = 256;

/// Static serving parameters.
#[derive(Debug, Clone, Default)]
pub struct ServerConfig {
    /// Where the `SNAPSHOT` RPC persists the registry. `None` makes the
    /// RPC answer [`ErrorCode::Unsupported`].
    pub snapshot_path: Option<PathBuf>,
}

/// Point-in-time server counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ServerStatsSnapshot {
    /// Connections accepted since start.
    pub connections: u64,
    /// Frames served (requests fully read, valid or not).
    pub frames: u64,
    /// Words ingested through `INSERT_BATCH`.
    pub words_ingested: u64,
    /// Requests answered with an `ERROR` frame.
    pub error_frames: u64,
}

#[derive(Debug, Default)]
struct ServerStats {
    connections: AtomicU64,
    frames: AtomicU64,
    words_ingested: AtomicU64,
    error_frames: AtomicU64,
}

#[derive(Debug)]
struct Shared {
    registry: Arc<SketchRegistry<u64>>,
    cfg: ServerConfig,
    stop: AtomicBool,
    stats: ServerStats,
}

/// A running sketch server. Dropping it performs a full graceful
/// shutdown (stop accepting, drain and join every connection thread).
pub struct SketchServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept_join: Option<JoinHandle<()>>,
}

impl SketchServer {
    /// Bind `addr` (use port 0 for an OS-assigned port) and start
    /// serving `registry`.
    pub fn start(
        addr: impl ToSocketAddrs,
        registry: Arc<SketchRegistry<u64>>,
        cfg: ServerConfig,
    ) -> io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        let shared = Arc::new(Shared {
            registry,
            cfg,
            stop: AtomicBool::new(false),
            stats: ServerStats::default(),
        });
        let accept_shared = shared.clone();
        let accept_join = std::thread::Builder::new()
            .name("sketch-server-accept".into())
            .spawn(move || accept_loop(listener, accept_shared))?;
        crate::log_debug!("server", "listening on {addr}");
        Ok(Self { addr, shared, accept_join: Some(accept_join) })
    }

    /// The bound address (with the real port when started on port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The registry this server fronts.
    pub fn registry(&self) -> &Arc<SketchRegistry<u64>> {
        &self.shared.registry
    }

    /// Aggregate serving counters.
    pub fn stats(&self) -> ServerStatsSnapshot {
        let s = &self.shared.stats;
        ServerStatsSnapshot {
            connections: s.connections.load(Ordering::Relaxed),
            frames: s.frames.load(Ordering::Relaxed),
            words_ingested: s.words_ingested.load(Ordering::Relaxed),
            error_frames: s.error_frames.load(Ordering::Relaxed),
        }
    }

    /// Graceful shutdown: stop accepting, join every connection thread.
    /// In-flight requests finish; idle connections close within the poll
    /// interval. Also runs on drop.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        // The accept loop polls nonblocking, so it observes the flag
        // within one poll interval on every platform and bind address
        // (no wake-up connection needed — one would not be routable for
        // wildcard binds everywhere).
        if let Some(join) = self.accept_join.take() {
            let _ = join.join();
        }
    }
}

impl Drop for SketchServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    // Nonblocking accept + short sleep: the loop observes the stop flag
    // within one poll interval, with no reliance on a wake-up connection
    // being able to reach the listener's bind address.
    let _ = listener.set_nonblocking(true);
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                // Accepted sockets can inherit the listener's
                // nonblocking mode on some platforms; connections use
                // blocking reads with a timeout.
                let _ = stream.set_nonblocking(false);
                let id = shared.stats.connections.fetch_add(1, Ordering::Relaxed) + 1;
                let conn_shared = shared.clone();
                let spawned = std::thread::Builder::new()
                    .name(format!("sketch-server-conn-{id}"))
                    .spawn(move || serve_connection(stream, conn_shared));
                if let Ok(join) = spawned {
                    conns.push(join);
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
        // Reap finished connections on every pass — including idle
        // polls, so a server that went quiet after a burst of
        // connections does not retain their join handles indefinitely.
        conns.retain(|j| !j.is_finished());
    }
    for join in conns {
        let _ = join.join();
    }
}

/// Fill `buf` from the stream, polling the stop flag across read
/// timeouts. `Ok(true)` = filled; `Ok(false)` = clean end (EOF before
/// the first byte, or server stopping); `Err` = broken stream or EOF
/// mid-frame.
fn read_full(stream: &mut TcpStream, buf: &mut [u8], stop: &AtomicBool) -> io::Result<bool> {
    let mut filled = 0;
    while filled < buf.len() {
        if stop.load(Ordering::SeqCst) {
            return Ok(false);
        }
        match stream.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(false);
                }
                return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "EOF mid-frame"));
            }
            Ok(n) => filled += n,
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::WouldBlock
                        | io::ErrorKind::TimedOut
                        | io::ErrorKind::Interrupted
                ) =>
            {
                continue
            }
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// Mirror of [`read_full`] for the reply side: drain `buf` into the
/// stream, polling the stop flag across write timeouts. Without this, a
/// peer that pipelines requests but never reads replies would fill the
/// socket buffers and park the connection thread in an unbounded
/// `write_all` — wedging [`SketchServer::shutdown`] forever.
fn write_full(stream: &mut TcpStream, buf: &[u8], stop: &AtomicBool) -> io::Result<bool> {
    let mut written = 0;
    while written < buf.len() {
        if stop.load(Ordering::SeqCst) {
            return Ok(false);
        }
        match stream.write(&buf[written..]) {
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::WriteZero,
                    "peer stopped accepting bytes",
                ))
            }
            Ok(n) => written += n,
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::WouldBlock
                        | io::ErrorKind::TimedOut
                        | io::ErrorKind::Interrupted
                ) =>
            {
                continue
            }
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

fn serve_connection(mut stream: TcpStream, shared: Arc<Shared>) {
    // Short poll intervals on both directions: the price of noticing
    // shutdown promptly on an idle connection (reads) and on a peer
    // that stops draining replies (writes).
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    let _ = stream.set_write_timeout(Some(Duration::from_millis(50)));
    let _ = stream.set_nodelay(true);
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_else(|_| "?".into());
    let mut conn_frames = 0u64;
    let mut conn_words = 0u64;

    loop {
        let mut header = [0u8; FRAME_HEADER_LEN];
        match read_full(&mut stream, &mut header, &shared.stop) {
            Ok(true) => {}
            _ => break,
        }
        let (opcode, len) = match parse_header(&header) {
            Ok(v) => v,
            Err(e) => {
                // Framing is broken; resync is impossible. Answer once,
                // then drop the connection.
                shared.stats.error_frames.fetch_add(1, Ordering::Relaxed);
                let err = Response::Error {
                    code: ErrorCode::Malformed,
                    message: e.to_string(),
                };
                let _ = write_full(&mut stream, &err.encode(), &shared.stop);
                break;
            }
        };
        let mut payload = vec![0u8; len as usize];
        match read_full(&mut stream, &mut payload, &shared.stop) {
            Ok(true) => {}
            _ => break,
        }
        conn_frames += 1;
        shared.stats.frames.fetch_add(1, Ordering::Relaxed);

        let resp = match Request::decode(opcode, &payload) {
            Ok(req) => {
                if let Request::InsertBatch { words, .. } = &req {
                    conn_words += words.len() as u64;
                }
                dispatch(req, &shared)
            }
            Err(e) => Response::Error { code: ErrorCode::Malformed, message: e.to_string() },
        };
        if matches!(resp, Response::Error { .. }) {
            shared.stats.error_frames.fetch_add(1, Ordering::Relaxed);
        }
        match write_full(&mut stream, &resp.encode(), &shared.stop) {
            Ok(true) => {}
            _ => break,
        }
    }
    crate::log_debug!("server", "connection {peer} closed: {conn_frames} frames, {conn_words} words");
}

fn dispatch(req: Request, shared: &Shared) -> Response {
    let registry = &shared.registry;
    match req {
        Request::Ping => Response::Pong,
        Request::InsertBatch { key, words } => {
            let n = words.len() as u64;
            registry.ingest(key, &words);
            shared.stats.words_ingested.fetch_add(n, Ordering::Relaxed);
            // A registry configured with a memory budget holds it without
            // every client having to know the cap: enforcement is
            // periodic because the accounting walk is O(keys).
            if registry.config().max_memory_bytes.is_some()
                && shared.stats.frames.load(Ordering::Relaxed) % BUDGET_ENFORCE_EVERY == 0
            {
                registry.enforce_budget();
            }
            Response::Ingested { words: n }
        }
        Request::Estimate { key } => Response::Estimate(registry.estimate(&key)),
        Request::GlobalEstimate => Response::GlobalEstimate(registry.global_estimate()),
        Request::MergeSketch { key, bytes } => match HllSketch::from_bytes(&bytes) {
            Ok(sketch) => match registry.merge_sketch(key, sketch) {
                Ok(()) => Response::Merged,
                Err(e @ SketchError::ConfigMismatch(..)) => Response::Error {
                    code: ErrorCode::ConfigMismatch,
                    message: e.to_string(),
                },
                Err(e) => {
                    Response::Error { code: ErrorCode::Malformed, message: e.to_string() }
                }
            },
            Err(e) => Response::Error { code: ErrorCode::Malformed, message: e.to_string() },
        },
        Request::Stats => Response::Stats(StatsSummary::from(&registry.stats())),
        Request::Evict(policy) => {
            let keys = match policy {
                EvictPolicy::Key(key) => registry.evict(&key).is_some() as u64,
                EvictPolicy::Idle { max_age } => registry.evict_idle(max_age) as u64,
                EvictPolicy::Budget { max_memory_bytes } => {
                    // Saturate rather than truncate: `as usize` would wrap
                    // a >= 4 GiB budget to ~0 on a 32-bit server and
                    // mass-evict the registry.
                    let budget = usize::try_from(max_memory_bytes).unwrap_or(usize::MAX);
                    registry.evict_to_budget(budget) as u64
                }
            };
            Response::Evicted { keys }
        }
        Request::Snapshot => match &shared.cfg.snapshot_path {
            Some(path) => match snapshot::write_snapshot(registry, path) {
                Ok(s) => Response::SnapshotDone { keys: s.keys, bytes: s.bytes },
                Err(e) => {
                    Response::Error { code: ErrorCode::Internal, message: e.to_string() }
                }
            },
            None => Response::Error {
                code: ErrorCode::Unsupported,
                message: "server started without a snapshot path".into(),
            },
        },
    }
}
