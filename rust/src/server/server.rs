//! The TCP serving front-end: a multi-threaded
//! [`std::net::TcpListener`] server that owns a shared
//! [`SketchRegistry`] and speaks the [`super::protocol`] frame protocol.
//!
//! One thread accepts; each connection gets a dedicated thread (the
//! blocking analogue of the paper's per-port NIC pipelines). The accept
//! loop and every connection read poll a shared stop flag on a short
//! interval, so [`SketchServer::shutdown`] (or drop) stops accepting
//! and joins every connection thread within one poll tick — a graceful
//! shutdown with no detached threads left touching the registry. Two
//! optional maintenance threads ride the same stop flag:
//!
//! * the **sweeper** ([`SweeperConfig`]) runs TTL / wall-clock-TTL /
//!   budget eviction on a timer, so lifecycle policy no longer depends
//!   on ingest traffic or explicit `Evict` RPCs;
//! * the **replication capture thread** ([`ReplicationConfig`]) drains
//!   the registry's dirty keys into the [`ReplicationLog`]'s sealed
//!   delta batches, which subscriber connections (`SUBSCRIBE` frames —
//!   see [`crate::replica`]) stream to followers with cursor resume and
//!   ack-window backpressure.
//!
//! With [`ServerConfig::read_only`] set the server fronts a replica:
//! mutating RPCs answer a typed [`ErrorCode::ReadOnly`] frame while
//! `Estimate` / `GlobalEstimate` / `Stats` / `Ping` serve normally.
//!
//! Malformed frames are answered with typed `ERROR` frames where the
//! stream is still in sync (decode errors), and the connection is
//! dropped where it cannot be (framing errors) — the server never
//! panics on hostile input.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::protocol::{
    encode_delta_batch, encode_delta_batch_v3, parse_header, ErrorCode, EvictPolicy, Request,
    Response, StatsSummary, DELTA_WIRE_V3, FRAME_HEADER_LEN, MAX_PAYLOAD,
};
use super::snapshot;
use crate::hll::{decode_register_diff, HllSketch, SketchError};
use crate::registry::{SketchDelta, SketchRegistry};
use crate::replica::{LogRead, ReplicationConfig, ReplicationLog, SealedBatch};

/// Ingest frames between server-driven
/// [`SketchRegistry::enforce_budget`] sweeps on a registry configured
/// with [`crate::registry::RegistryConfig::max_memory_bytes`]. The
/// sweep's accounting walk is O(keys), so it is amortized rather than
/// run per batch; the budget is a soft target either way. (The
/// background sweeper, when configured, enforces on its timer as well —
/// this piggyback remains for servers run without one.)
const BUDGET_ENFORCE_EVERY: u64 = 256;

/// Background maintenance sweeper parameters: which eviction policies
/// run on the timer (ROADMAP item — previously budget enforcement only
/// piggybacked on ingest frames and the `Evict` RPC).
#[derive(Debug, Clone)]
pub struct SweeperConfig {
    /// Pause between maintenance passes.
    pub interval: Duration,
    /// Run [`SketchRegistry::evict_idle`] with this logical-tick TTL on
    /// every pass.
    pub idle_max_ticks: Option<u64>,
    /// Run [`SketchRegistry::evict_idle_wall`] with this wall-clock TTL
    /// on every pass.
    pub idle_max_age: Option<Duration>,
    /// Run [`SketchRegistry::enforce_budget`] on every pass (no-op on
    /// registries without a configured budget).
    pub enforce_budget: bool,
}

impl Default for SweeperConfig {
    fn default() -> Self {
        Self {
            interval: Duration::from_millis(200),
            idle_max_ticks: None,
            idle_max_age: None,
            enforce_budget: true,
        }
    }
}

/// Static serving parameters.
#[derive(Debug, Clone, Default)]
pub struct ServerConfig {
    /// Where the `SNAPSHOT` RPC persists the registry. `None` makes the
    /// RPC answer [`ErrorCode::Unsupported`].
    pub snapshot_path: Option<PathBuf>,
    /// Serve as a read-only replica front-end: `InsertBatch`,
    /// `MergeSketch`, `Evict` and `Snapshot` answer
    /// [`ErrorCode::ReadOnly`]. [`crate::replica::FollowerServer`] sets
    /// this on the server it wraps.
    pub read_only: bool,
    /// Act as a replication primary: enable dirty tracking on the
    /// registry, run the capture thread, and accept `SUBSCRIBE`
    /// streams. `None` makes `SUBSCRIBE` answer
    /// [`ErrorCode::Unsupported`].
    pub replication: Option<ReplicationConfig>,
    /// Run the background maintenance sweeper.
    pub sweeper: Option<SweeperConfig>,
}

/// Point-in-time server counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ServerStatsSnapshot {
    /// Connections accepted since start.
    pub connections: u64,
    /// Frames served (requests fully read, valid or not).
    pub frames: u64,
    /// Words ingested through `INSERT_BATCH`.
    pub words_ingested: u64,
    /// Requests answered with an `ERROR` frame.
    pub error_frames: u64,
    /// Background sweeper passes completed.
    pub sweeps: u64,
    /// Keys evicted by background sweeper passes.
    pub keys_swept: u64,
    /// `DELTA_BATCH` frames streamed to subscribers.
    pub delta_batches_sent: u64,
    /// `FULL_SYNC` frames streamed to subscribers (bootstraps plus
    /// stale-cursor fallbacks).
    pub full_syncs_sent: u64,
}

#[derive(Debug, Default)]
struct ServerStats {
    connections: AtomicU64,
    frames: AtomicU64,
    words_ingested: AtomicU64,
    error_frames: AtomicU64,
    sweeps: AtomicU64,
    keys_swept: AtomicU64,
    delta_batches_sent: AtomicU64,
    full_syncs_sent: AtomicU64,
}

#[derive(Debug)]
struct Shared {
    registry: Arc<SketchRegistry<u64>>,
    cfg: ServerConfig,
    stop: AtomicBool,
    stats: ServerStats,
    /// Present iff this server is a replication primary.
    log: Option<Arc<ReplicationLog>>,
}

/// A running sketch server. Dropping it performs a full graceful
/// shutdown (stop accepting, drain and join every connection thread).
pub struct SketchServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept_join: Option<JoinHandle<()>>,
    /// Sweeper and replication-capture threads, joined on shutdown like
    /// the accept thread.
    maint_joins: Vec<JoinHandle<()>>,
}

impl SketchServer {
    /// Bind `addr` (use port 0 for an OS-assigned port) and start
    /// serving `registry`.
    pub fn start(
        addr: impl ToSocketAddrs,
        registry: Arc<SketchRegistry<u64>>,
        cfg: ServerConfig,
    ) -> io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        // A replication primary needs dirty tracking on before any
        // subscriber can connect: every mutation then either lands in a
        // subscriber's bootstrap full sync (it ran before the accept
        // thread existed) or in a sealed delta batch — never in
        // neither. Enabled only after the fallible bind, so a failed
        // start does not leave the shared registry accumulating dirty
        // keys that nothing will ever drain.
        let log = cfg.replication.as_ref().map(|_| {
            registry.enable_dirty_tracking();
            Arc::new(ReplicationLog::new())
        });
        let shared = Arc::new(Shared {
            registry,
            cfg,
            stop: AtomicBool::new(false),
            stats: ServerStats::default(),
            log,
        });
        let mut maint_joins = Vec::new();
        if let (Some(log), Some(rcfg)) = (&shared.log, &shared.cfg.replication) {
            let capture_shared = shared.clone();
            let capture_log = log.clone();
            let capture_cfg = rcfg.clone();
            maint_joins.push(
                std::thread::Builder::new()
                    .name("sketch-server-capture".into())
                    .spawn(move || capture_loop(capture_shared, capture_log, capture_cfg))?,
            );
        }
        if let Some(scfg) = &shared.cfg.sweeper {
            let sweep_shared = shared.clone();
            let sweep_cfg = scfg.clone();
            maint_joins.push(
                std::thread::Builder::new()
                    .name("sketch-server-sweeper".into())
                    .spawn(move || sweeper_loop(sweep_shared, sweep_cfg))?,
            );
        }
        let accept_shared = shared.clone();
        let accept_join = std::thread::Builder::new()
            .name("sketch-server-accept".into())
            .spawn(move || accept_loop(listener, accept_shared))?;
        crate::log_debug!("server", "listening on {addr}");
        Ok(Self { addr, shared, accept_join: Some(accept_join), maint_joins })
    }

    /// The bound address (with the real port when started on port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The registry this server fronts.
    pub fn registry(&self) -> &Arc<SketchRegistry<u64>> {
        &self.shared.registry
    }

    /// Aggregate serving counters.
    pub fn stats(&self) -> ServerStatsSnapshot {
        let s = &self.shared.stats;
        ServerStatsSnapshot {
            connections: s.connections.load(Ordering::Relaxed),
            frames: s.frames.load(Ordering::Relaxed),
            words_ingested: s.words_ingested.load(Ordering::Relaxed),
            error_frames: s.error_frames.load(Ordering::Relaxed),
            sweeps: s.sweeps.load(Ordering::Relaxed),
            keys_swept: s.keys_swept.load(Ordering::Relaxed),
            delta_batches_sent: s.delta_batches_sent.load(Ordering::Relaxed),
            full_syncs_sent: s.full_syncs_sent.load(Ordering::Relaxed),
        }
    }

    /// The replication log this primary seals delta batches into
    /// (`None` unless started with [`ServerConfig::replication`]).
    /// Tests and benches use it to force a synchronous capture
    /// ([`ReplicationLog::capture`]) and to read the latest sealed seq.
    pub fn replication_log(&self) -> Option<&Arc<ReplicationLog>> {
        self.shared.log.as_ref()
    }

    /// Graceful shutdown: stop accepting, join every connection thread.
    /// In-flight requests finish; idle connections close within the poll
    /// interval. Also runs on drop.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        // The accept loop polls nonblocking, so it observes the flag
        // within one poll interval on every platform and bind address
        // (no wake-up connection needed — one would not be routable for
        // wildcard binds everywhere).
        if let Some(join) = self.accept_join.take() {
            let _ = join.join();
        }
        for join in self.maint_joins.drain(..) {
            let _ = join.join();
        }
    }
}

impl Drop for SketchServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    // Nonblocking accept + short sleep: the loop observes the stop flag
    // within one poll interval, with no reliance on a wake-up connection
    // being able to reach the listener's bind address.
    let _ = listener.set_nonblocking(true);
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                // Accepted sockets can inherit the listener's
                // nonblocking mode on some platforms; connections use
                // blocking reads with a timeout.
                let _ = stream.set_nonblocking(false);
                let id = shared.stats.connections.fetch_add(1, Ordering::Relaxed) + 1;
                let conn_shared = shared.clone();
                let spawned = std::thread::Builder::new()
                    .name(format!("sketch-server-conn-{id}"))
                    .spawn(move || serve_connection(stream, conn_shared));
                if let Ok(join) = spawned {
                    conns.push(join);
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
        // Reap finished connections on every pass — including idle
        // polls, so a server that went quiet after a burst of
        // connections does not retain their join handles indefinitely.
        conns.retain(|j| !j.is_finished());
    }
    for join in conns {
        let _ = join.join();
    }
}

/// Fill `buf` from the stream, polling the stop flag across read
/// timeouts. `Ok(true)` = filled; `Ok(false)` = clean end (EOF before
/// the first byte, or server stopping); `Err` = broken stream or EOF
/// mid-frame. Shared with [`crate::replica`]'s follower loop.
pub(crate) fn read_full(
    stream: &mut TcpStream,
    buf: &mut [u8],
    stop: &AtomicBool,
) -> io::Result<bool> {
    let mut filled = 0;
    while filled < buf.len() {
        if stop.load(Ordering::SeqCst) {
            return Ok(false);
        }
        match stream.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(false);
                }
                return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "EOF mid-frame"));
            }
            Ok(n) => filled += n,
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::WouldBlock
                        | io::ErrorKind::TimedOut
                        | io::ErrorKind::Interrupted
                ) =>
            {
                continue
            }
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// Mirror of [`read_full`] for the reply side: drain `buf` into the
/// stream, polling the stop flag across write timeouts. Without this, a
/// peer that pipelines requests but never reads replies would fill the
/// socket buffers and park the connection thread in an unbounded
/// `write_all` — wedging [`SketchServer::shutdown`] forever.
pub(crate) fn write_full(stream: &mut TcpStream, buf: &[u8], stop: &AtomicBool) -> io::Result<bool> {
    let mut written = 0;
    while written < buf.len() {
        if stop.load(Ordering::SeqCst) {
            return Ok(false);
        }
        match stream.write(&buf[written..]) {
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::WriteZero,
                    "peer stopped accepting bytes",
                ))
            }
            Ok(n) => written += n,
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::WouldBlock
                        | io::ErrorKind::TimedOut
                        | io::ErrorKind::Interrupted
                ) =>
            {
                continue
            }
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// Try to read one complete raw frame, returning `Ok(None)` when the
/// stream's read timeout expires before the first byte arrives (the
/// caller's idle tick). Once a first byte is in, the rest of the frame
/// is read to completion ([`read_full`] semantics, stop-flag aware). A
/// clean EOF, a stop mid-frame, or a bad header all surface as `Err` —
/// replication streams treat every error as "drop the connection".
/// Shared by the primary's subscriber loop (reading acks between batch
/// sends) and the follower's apply loop (reading batches between
/// reconnect checks).
pub(crate) fn try_read_frame(
    stream: &mut TcpStream,
    stop: &AtomicBool,
) -> io::Result<Option<(u8, Vec<u8>)>> {
    let mut header = [0u8; FRAME_HEADER_LEN];
    let first = match stream.read(&mut header) {
        Ok(0) => return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "peer closed")),
        Ok(n) => n,
        Err(e)
            if matches!(
                e.kind(),
                io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut | io::ErrorKind::Interrupted
            ) =>
        {
            return Ok(None)
        }
        Err(e) => return Err(e),
    };
    if first < FRAME_HEADER_LEN && !read_full(stream, &mut header[first..], stop)? {
        return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "EOF mid-header"));
    }
    let (opcode, len) = parse_header(&header)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()))?;
    let mut payload = vec![0u8; len as usize];
    if len > 0 && !read_full(stream, &mut payload, stop)? {
        return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "EOF mid-payload"));
    }
    Ok(Some((opcode, payload)))
}

/// Replication capture thread: drain the registry's dirty keys into a
/// sealed [`ReplicationLog`] batch on the configured cadence. One
/// capturer per primary; subscriber connections only *read* the log.
fn capture_loop(shared: Arc<Shared>, log: Arc<ReplicationLog>, cfg: ReplicationConfig) {
    let mut last = Instant::now();
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
        if last.elapsed() < cfg.capture_interval {
            continue;
        }
        last = Instant::now();
        log.capture(&shared.registry, cfg.retain_bytes);
    }
}

/// Background maintenance sweeper: timer-driven TTL / wall-TTL / budget
/// eviction (previously only reachable through ingest piggybacking and
/// the `Evict` RPC). Polls the stop flag between short sleeps so
/// shutdown joins it within a few milliseconds regardless of the
/// configured interval.
fn sweeper_loop(shared: Arc<Shared>, cfg: SweeperConfig) {
    let mut last = Instant::now();
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
        if last.elapsed() < cfg.interval {
            continue;
        }
        last = Instant::now();
        let mut swept = 0usize;
        if let Some(max_ticks) = cfg.idle_max_ticks {
            swept += shared.registry.evict_idle(max_ticks);
        }
        if let Some(max_age) = cfg.idle_max_age {
            swept += shared.registry.evict_idle_wall(max_age);
        }
        if cfg.enforce_budget {
            swept += shared.registry.enforce_budget();
        }
        shared.stats.sweeps.fetch_add(1, Ordering::Relaxed);
        shared.stats.keys_swept.fetch_add(swept as u64, Ordering::Relaxed);
        if swept > 0 {
            crate::log_debug!("server", "sweeper evicted {swept} keys");
        }
    }
}

/// Ship a complete registry image to a subscriber whose cursor the log
/// cannot serve (bootstrap, or fell behind retention). The cursor is
/// read *before* the export: anything ingested in between lands either
/// in the image (a harmless duplicate under max-merge) or in a batch
/// with seq > cursor that streams right after. Returns `false` when the
/// connection is no longer usable.
fn send_full_sync(
    stream: &mut TcpStream,
    shared: &Shared,
    log: &ReplicationLog,
    sent: &mut u64,
    acked: &mut u64,
) -> bool {
    let cursor = log.latest_seq();
    let body = snapshot::snapshot_to_vec(&shared.registry);
    // A FULL_SYNC payload is epoch (8) + cursor (8) + len (4) + body.
    if body.len() as u64 + 20 > MAX_PAYLOAD as u64 {
        let err = Response::Error {
            code: ErrorCode::Internal,
            message: format!(
                "registry image of {} bytes exceeds the in-band full-sync frame cap; \
                 bootstrap this follower from a snapshot file",
                body.len()
            ),
        };
        let _ = write_full(stream, &err.encode(), &shared.stop);
        return false;
    }
    let frame = Response::FullSync { epoch: log.epoch(), cursor, body }.encode();
    if !matches!(write_full(stream, &frame, &shared.stop), Ok(true)) {
        return false;
    }
    shared.stats.full_syncs_sent.fetch_add(1, Ordering::Relaxed);
    *sent = cursor;
    *acked = cursor;
    true
}

/// Encode one sealed batch for a subscriber's negotiated delta wire.
/// Current (v3) subscribers get the typed entries verbatim; legacy
/// (v2) subscribers get the shape they understand — full sketches only:
/// register diffs inflate into a sketch holding just those registers
/// (zeros never lower anything under max-merge), and tombstones are
/// dropped, leaving legacy followers grow-only exactly as they were
/// before tombstones existed. An emptied batch still ships, so the
/// subscriber's cursor advances past it.
///
/// Returns `None` when the legacy rendering cannot fit one frame: the
/// batch was size-budgeted in *diff* bytes, and inflating every diff to
/// a full 2^p-byte sketch can multiply it past [`MAX_PAYLOAD`] (~3600×
/// at the paper config in the worst case). The running size is checked
/// before each sketch is materialized — an overflowing batch allocates
/// at most the frame cap before bailing — and the caller answers a
/// terminal typed error instead of streaming a frame the follower's
/// header parser would reject on every reconnect forever.
fn encode_batch_for_wire(batch: &SealedBatch, wire: u8) -> Option<Vec<u8>> {
    if wire >= DELTA_WIRE_V3 {
        return Some(encode_delta_batch_v3(batch.seq, &batch.entries));
    }
    let mut legacy: Vec<(u64, Vec<u8>)> = Vec::with_capacity(batch.entries.len());
    let mut total = 12u64;
    for (key, delta) in &batch.entries {
        match delta {
            SketchDelta::Full(bytes) => {
                total += 12 + bytes.len() as u64;
                if total > MAX_PAYLOAD as u64 {
                    return None;
                }
                legacy.push((*key, bytes.clone()));
            }
            SketchDelta::RegisterDiff(bytes) => {
                // Sealed diffs came from our own drain; a decode failure
                // here would be a local invariant break, so skipping the
                // entry (follower falls back to grow-only staleness for
                // that key until its next full resend) beats wedging the
                // stream.
                if let Ok((cfg, entries)) = decode_register_diff(bytes) {
                    total += 12 + HllSketch::wire_len(&cfg) as u64;
                    if total > MAX_PAYLOAD as u64 {
                        return None;
                    }
                    let mut sketch = HllSketch::new(cfg);
                    sketch.apply_register_diff(&entries);
                    legacy.push((*key, sketch.to_bytes()));
                }
            }
            SketchDelta::Tombstone => {}
        }
    }
    Some(encode_delta_batch(batch.seq, &legacy))
}

/// A connection that sent `SUBSCRIBE`: stream sealed delta batches (and
/// full syncs where the cursor is unservable), reading `REPLICA_ACK`
/// frames back on the same socket. At most
/// [`ReplicationConfig::ack_window`] batches ride unacked — a slow
/// follower exerts backpressure here instead of ballooning socket
/// buffers. Returns when the peer disconnects, misbehaves, or the
/// server stops.
fn serve_subscriber(
    stream: &mut TcpStream,
    shared: &Shared,
    log: Arc<ReplicationLog>,
    sub_epoch: u64,
    start_cursor: u64,
    wire: u8,
) {
    let rcfg = shared.cfg.replication.clone().unwrap_or_default();
    // Tighter read timeout than RPC connections: the ack read doubles
    // as the pacing sleep between log polls, and 50 ms of added
    // shipping latency per window would dominate convergence lag.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(10)));
    let mut sent = start_cursor;
    let mut acked = start_cursor;
    // Bootstrap (cursor 0 = "I have nothing") always full-syncs: the
    // registry may predate the log (pre-serving ingest, a restored
    // snapshot). So does a cursor issued by a *different* log
    // incarnation — a restarted primary resets seq numbering, and
    // without the epoch check an old cursor could alias into the new
    // log's range and silently skip its early batches.
    if (start_cursor == 0 || sub_epoch != log.epoch())
        && !send_full_sync(stream, shared, &log, &mut sent, &mut acked)
    {
        return;
    }
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        // Ship whatever the log holds past our position, within the
        // unacked window.
        while sent.saturating_sub(acked) < rcfg.ack_window {
            match log.read_after(sent) {
                LogRead::Batch(batch) => {
                    let Some(frame) = encode_batch_for_wire(&batch, wire) else {
                        // Only legacy renderings can overflow; a v2
                        // follower cannot take this batch in any form,
                        // and Internal is in its terminal-halt set.
                        let err = Response::Error {
                            code: ErrorCode::Internal,
                            message: format!(
                                "batch {} inflates past the legacy frame cap; upgrade the \
                                 follower to delta wire v3 or bootstrap it from a snapshot",
                                batch.seq
                            ),
                        };
                        let _ = write_full(stream, &err.encode(), &shared.stop);
                        return;
                    };
                    if !matches!(write_full(stream, &frame, &shared.stop), Ok(true)) {
                        return;
                    }
                    sent = batch.seq;
                    shared.stats.delta_batches_sent.fetch_add(1, Ordering::Relaxed);
                }
                LogRead::CaughtUp => break,
                LogRead::Stale => {
                    // Fell behind retention (or resumed with a cursor
                    // from a previous primary incarnation): resync.
                    if !send_full_sync(stream, shared, &log, &mut sent, &mut acked) {
                        return;
                    }
                }
            }
        }
        // One read-timeout's worth of waiting for an ack — also the
        // idle tick when there is nothing to ship.
        match try_read_frame(stream, &shared.stop) {
            Ok(None) => {}
            Ok(Some((opcode, payload))) => match Request::decode(opcode, &payload) {
                Ok(Request::ReplicaAck { cursor }) => {
                    // Clamp to what was actually sent: a buggy follower
                    // cannot push the window past reality.
                    acked = acked.max(cursor.min(sent));
                }
                _ => {
                    let err = Response::Error {
                        code: ErrorCode::Malformed,
                        message: "only ReplicaAck frames are valid on a subscription stream"
                            .into(),
                    };
                    let _ = write_full(stream, &err.encode(), &shared.stop);
                    return;
                }
            },
            Err(_) => return,
        }
    }
}

fn serve_connection(mut stream: TcpStream, shared: Arc<Shared>) {
    // Short poll intervals on both directions: the price of noticing
    // shutdown promptly on an idle connection (reads) and on a peer
    // that stops draining replies (writes).
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    let _ = stream.set_write_timeout(Some(Duration::from_millis(50)));
    let _ = stream.set_nodelay(true);
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_else(|_| "?".into());
    let mut conn_frames = 0u64;
    let mut conn_words = 0u64;

    loop {
        let mut header = [0u8; FRAME_HEADER_LEN];
        match read_full(&mut stream, &mut header, &shared.stop) {
            Ok(true) => {}
            _ => break,
        }
        let (opcode, len) = match parse_header(&header) {
            Ok(v) => v,
            Err(e) => {
                // Framing is broken; resync is impossible. Answer once,
                // then drop the connection.
                shared.stats.error_frames.fetch_add(1, Ordering::Relaxed);
                let err = Response::Error {
                    code: ErrorCode::Malformed,
                    message: e.to_string(),
                };
                let _ = write_full(&mut stream, &err.encode(), &shared.stop);
                break;
            }
        };
        let mut payload = vec![0u8; len as usize];
        match read_full(&mut stream, &mut payload, &shared.stop) {
            Ok(true) => {}
            _ => break,
        }
        conn_frames += 1;
        shared.stats.frames.fetch_add(1, Ordering::Relaxed);

        let resp = match Request::decode(opcode, &payload) {
            Ok(Request::Subscribe { epoch, cursor, wire }) => {
                // The connection becomes a replication stream and never
                // returns to request/response serving.
                if let Some(log) = shared.log.clone() {
                    serve_subscriber(&mut stream, &shared, log, epoch, cursor, wire);
                    break;
                }
                Response::Error {
                    code: ErrorCode::Unsupported,
                    message: "server is not a replication primary".into(),
                }
            }
            Ok(Request::ReplicaAck { .. }) => Response::Error {
                code: ErrorCode::Malformed,
                message: "ReplicaAck outside an active subscription".into(),
            },
            Ok(req) => {
                if let Request::InsertBatch { words, .. } = &req {
                    conn_words += words.len() as u64;
                }
                dispatch(req, &shared)
            }
            Err(e) => Response::Error { code: ErrorCode::Malformed, message: e.to_string() },
        };
        if matches!(resp, Response::Error { .. }) {
            shared.stats.error_frames.fetch_add(1, Ordering::Relaxed);
        }
        match write_full(&mut stream, &resp.encode(), &shared.stop) {
            Ok(true) => {}
            _ => break,
        }
    }
    crate::log_debug!("server", "connection {peer} closed: {conn_frames} frames, {conn_words} words");
}

fn dispatch(req: Request, shared: &Shared) -> Response {
    let registry = &shared.registry;
    // A read-only replica rejects every mutating RPC with a typed frame
    // before touching the registry; queries pass through untouched.
    if shared.cfg.read_only
        && matches!(
            req,
            Request::InsertBatch { .. }
                | Request::MergeSketch { .. }
                | Request::Evict(_)
                | Request::Snapshot
        )
    {
        return Response::Error {
            code: ErrorCode::ReadOnly,
            message: "replica is read-only; send writes to the primary".into(),
        };
    }
    match req {
        Request::Ping => Response::Pong,
        Request::InsertBatch { key, words } => {
            let n = words.len() as u64;
            registry.ingest(key, &words);
            shared.stats.words_ingested.fetch_add(n, Ordering::Relaxed);
            // A registry configured with a memory budget holds it without
            // every client having to know the cap: enforcement is
            // periodic because the accounting walk is O(keys).
            if registry.config().max_memory_bytes.is_some()
                && shared.stats.frames.load(Ordering::Relaxed) % BUDGET_ENFORCE_EVERY == 0
            {
                registry.enforce_budget();
            }
            Response::Ingested { words: n }
        }
        Request::Estimate { key } => Response::Estimate(registry.estimate(&key)),
        Request::GlobalEstimate => Response::GlobalEstimate(registry.global_estimate()),
        Request::MergeSketch { key, bytes } => match HllSketch::from_bytes(&bytes) {
            Ok(sketch) => match registry.merge_sketch(key, sketch) {
                Ok(()) => Response::Merged,
                Err(e @ SketchError::ConfigMismatch(..)) => Response::Error {
                    code: ErrorCode::ConfigMismatch,
                    message: e.to_string(),
                },
                Err(e) => {
                    Response::Error { code: ErrorCode::Malformed, message: e.to_string() }
                }
            },
            Err(e) => Response::Error { code: ErrorCode::Malformed, message: e.to_string() },
        },
        Request::Stats => Response::Stats(StatsSummary::from(&registry.stats())),
        Request::Evict(policy) => {
            let keys = match policy {
                EvictPolicy::Key(key) => registry.evict(&key).is_some() as u64,
                EvictPolicy::Idle { max_age } => registry.evict_idle(max_age) as u64,
                EvictPolicy::Budget { max_memory_bytes } => {
                    // Saturate rather than truncate: `as usize` would wrap
                    // a >= 4 GiB budget to ~0 on a 32-bit server and
                    // mass-evict the registry.
                    let budget = usize::try_from(max_memory_bytes).unwrap_or(usize::MAX);
                    registry.evict_to_budget(budget) as u64
                }
                EvictPolicy::IdleWall { max_age_secs } => {
                    registry.evict_idle_wall(Duration::from_secs(max_age_secs)) as u64
                }
            };
            Response::Evicted { keys }
        }
        Request::Snapshot => match &shared.cfg.snapshot_path {
            Some(path) => match snapshot::write_snapshot(registry, path) {
                Ok(s) => Response::SnapshotDone { keys: s.keys, bytes: s.bytes },
                Err(e) => {
                    Response::Error { code: ErrorCode::Internal, message: e.to_string() }
                }
            },
            None => Response::Error {
                code: ErrorCode::Unsupported,
                message: "server started without a snapshot path".into(),
            },
        },
        // Handled at the connection layer (serve_connection) before
        // dispatch; unreachable in practice, answered typed regardless.
        Request::Subscribe { .. } | Request::ReplicaAck { .. } => Response::Error {
            code: ErrorCode::Malformed,
            message: "replication frames are handled at the connection layer".into(),
        },
    }
}
