//! The TCP serving front-end: an event-driven, nonblocking readiness
//! loop ([`super::reactor`]) multiplexing every connection through one
//! (configurably N) loop thread — the software analogue of the paper's
//! single shared FPGA datapath that many flows progress through
//! concurrently, replacing the old thread-per-connection model whose
//! cost scaled with *open* connections rather than *active* ones.
//!
//! # Connection state machine
//!
//! Each accepted socket is a [`Conn`]: a nonblocking stream plus an
//! incremental [`FrameDecoder`] (inbound) and [`FrameEncoder`]
//! (outbound). Readiness events drive it through:
//!
//! ```text
//!            readable: bytes → decoder
//!   ┌────────────────────────────────────────┐
//!   ▼                                        │
//! Reading ──frame──▶ Dispatching ──reply──▶ Writing ──drained──▶ (Reading)
//!   │                     │ SUBSCRIBE                ▲
//!   │ framing error       ▼                          │ log wakeup /
//!   ▼                 Subscribed ◀───────────────────┘ REPLICA_ACK
//! Closing (flush the typed error, then drop)
//! ```
//!
//! * **Reading** — readable events append to the decoder; frames left
//!   suspended mid-read and completed later feed the
//!   `partial_frames_resumed` stat (a slow-loris client trickling one
//!   byte per frame costs buffered bytes, not a parked thread).
//! * **Dispatching** — complete frames dispatch exactly as before
//!   (same [`dispatch`]); payload-level decode errors answer a typed
//!   `ERROR` and keep serving, framing errors answer once and close.
//! * **Writing** — replies queue in the encoder and drain on writable
//!   events. **Backpressure is interest flipping**: past a buffered
//!   threshold the connection's read interest is dropped, so a peer
//!   that never reads replies stalls *itself* (TCP flow control pushes
//!   back through its own socket) while every other connection
//!   progresses. No write ever blocks the loop.
//! * **Subscribed** — a `SUBSCRIBE` frame flips the connection into a
//!   nonblocking replication stream: sealed batches are pumped into
//!   the encoder within the ack window and a byte budget, `REPLICA_ACK`
//!   frames slide the window, and the capture thread [`Waker`]-wakes
//!   every loop after sealing so write interest re-arms within one
//!   syscall instead of one poll tick.
//!
//! Idle connections ([`ServerConfig::idle_timeout`]) are reaped by the
//! loop's tick sweep; [`ServerConfig::max_connections`] stops accepting
//! (the listener leaves the interest set) until the count drops.
//! Graceful shutdown raises the stop flag, wakes every loop, drains the
//! pollers, best-effort-flushes queued replies and joins the loop
//! threads — no per-connection threads exist to join.
//!
//! # Syscall-lean serving (the C100K path)
//!
//! * The readiness substrate is backend-selectable
//!   ([`ServerConfig::poller_backend`], default best available, env
//!   override `HLL_POLLER`): `epoll` on Linux keeps persistent kernel
//!   interest and mutates it only on state change, so a steady tick is
//!   one syscall regardless of resident connections; `poll(2)` remains
//!   the portable fallback.
//! * With several loops on Linux, each loop gets its *own* listener on
//!   the shared port via `SO_REUSEPORT` ([`super::reuseport`]): the
//!   kernel shards accepts across loops and each loop admits locally —
//!   no cross-thread routing channel on the accept path. Where
//!   unavailable, loop 0 owns the single listener and routes accepted
//!   sockets round-robin as before.
//! * Reply draining is vectored: queued frames are gathered into one
//!   `writev(2)` per flush, so a pipelined burst of small replies
//!   costs one syscall instead of one per frame.
//! * Blocking work leaves the loop: with
//!   [`ServerConfig::worker_threads`] > 0, the `Snapshot` RPC's file
//!   write and a subscriber full-sync's registry-image serialization
//!   run on a small worker pool; the owning loop halts just that
//!   connection (preserving pipelined reply order), is woken through
//!   its [`Waker`] on completion, and delivers the result from its
//!   per-loop completion queue.
//!
//! Two optional maintenance threads ride the same stop flag:
//!
//! * the **sweeper** ([`SweeperConfig`]) runs TTL / wall-clock-TTL /
//!   budget eviction on a timer;
//! * the **replication capture thread** ([`ReplicationConfig`]) drains
//!   the registry's dirty keys (and the global union's dirty registers)
//!   into the [`ReplicationLog`]'s sealed delta batches, then wakes the
//!   event loops so subscriber connections ship them.
//!
//! With [`ServerConfig::read_only`] set the server fronts a replica:
//! mutating RPCs answer a typed [`ErrorCode::ReadOnly`] frame while
//! `Estimate` / `GlobalEstimate` / `Stats` / `Ping` serve normally.
//! Malformed frames are answered with typed `ERROR` frames where the
//! stream is still in sync (decode errors), and the connection is
//! dropped where it cannot be (framing errors) — the server never
//! panics on hostile input.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::os::unix::io::AsRawFd;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::protocol::{
    encode_delta_batch, encode_delta_batch_v3, encode_delta_batch_v4, opcodes,
    request_opcode_name, split_trace_ctx, ErrorCode, EvictPolicy, FrameDecoder, FrameEncoder,
    Request, Response, StatsSummary, DELTA_WIRE_V3, DELTA_WIRE_V4, MAX_PAYLOAD,
    REQUEST_OPCODE_MAX,
};
use super::reactor::{self, Poller, PollerBackend, TickProfile, WakeRx, Waker};
use super::reuseport;
use super::snapshot;
use crate::hll::{decode_register_diff, HllSketch, SketchError};
use crate::obs::recorder;
use crate::obs::trace::{EventKind, Span, Stage, StageTimers, TraceEvent};
use crate::obs::{Counter, Gauge, LatencyHistogram, MetricsRegistry};
use crate::registry::{SketchDelta, SketchRegistry};
use crate::replica::{LogRead, ReplicationConfig, ReplicationLog, SealedBatch};

/// Ingest frames between server-driven
/// [`SketchRegistry::enforce_budget`] sweeps on a registry configured
/// with [`crate::registry::RegistryConfig::max_memory_bytes`]. The
/// sweep's accounting walk is O(keys), so it is amortized rather than
/// run per batch; the budget is a soft target either way. (The
/// background sweeper, when configured, enforces on its timer as well —
/// this piggyback remains for servers run without one.)
const BUDGET_ENFORCE_EVERY: u64 = 256;

/// Buffered reply bytes past which a connection's *read* interest is
/// dropped (write backpressure): the peer stops being served new
/// requests until it drains what it already owes us. Well above one
/// full pipeline window of replies, so normal pipelining never pauses.
const READ_PAUSE_BYTES: usize = 256 * 1024;

/// Per-readiness-event read budget: one connection may buffer at most
/// this much in a single burst before the loop moves on (fairness
/// against a firehose peer; level-triggered poll re-reports the rest).
const READ_BURST_BYTES: usize = 1 << 20;

/// Outbound byte budget a subscriber pump keeps queued. Bounds the
/// encoder's memory to roughly one batch above this (batches are capped
/// at `MAX_PAYLOAD / 4`), instead of `ack_window × batch` bytes.
const SUB_PUMP_TARGET: usize = 1 << 20;

/// Poll tick: upper bound on how late the loop notices timer-ish work
/// (idle sweeps, manually sealed batches in tests). Stop and capture
/// wakeups arrive via the waker, not the tick.
const POLL_TICK: Duration = Duration::from_millis(25);

/// Most recent flight-recorder events a `TraceDump` reply carries
/// (4096 × 26 wire bytes ≈ 104 KiB, far under `MAX_PAYLOAD`).
const TRACE_DUMP_MAX_EVENTS: usize = 4096;

/// Poll tokens for the two non-connection descriptors.
const TOKEN_WAKER: usize = usize::MAX;
const TOKEN_LISTENER: usize = usize::MAX - 1;

/// Background maintenance sweeper parameters: which eviction policies
/// run on the timer (ROADMAP item — previously budget enforcement only
/// piggybacked on ingest frames and the `Evict` RPC).
#[derive(Debug, Clone)]
pub struct SweeperConfig {
    /// Pause between maintenance passes.
    pub interval: Duration,
    /// Run [`SketchRegistry::evict_idle`] with this logical-tick TTL on
    /// every pass.
    pub idle_max_ticks: Option<u64>,
    /// Run [`SketchRegistry::evict_idle_wall`] with this wall-clock TTL
    /// on every pass.
    pub idle_max_age: Option<Duration>,
    /// Run [`SketchRegistry::enforce_budget`] on every pass (no-op on
    /// registries without a configured budget).
    pub enforce_budget: bool,
}

impl Default for SweeperConfig {
    fn default() -> Self {
        Self {
            interval: Duration::from_millis(200),
            idle_max_ticks: None,
            idle_max_age: None,
            enforce_budget: true,
        }
    }
}

/// Static serving parameters.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Where the `SNAPSHOT` RPC persists the registry. `None` makes the
    /// RPC answer [`ErrorCode::Unsupported`].
    pub snapshot_path: Option<PathBuf>,
    /// Serve as a read-only replica front-end: `InsertBatch`,
    /// `MergeSketch`, `Evict` and `Snapshot` answer
    /// [`ErrorCode::ReadOnly`]. [`crate::replica::FollowerServer`] sets
    /// this on the server it wraps.
    pub read_only: bool,
    /// Act as a replication primary: enable dirty tracking on the
    /// registry, run the capture thread, and accept `SUBSCRIBE`
    /// streams. `None` makes `SUBSCRIBE` answer
    /// [`ErrorCode::Unsupported`].
    pub replication: Option<ReplicationConfig>,
    /// Run the background maintenance sweeper.
    pub sweeper: Option<SweeperConfig>,
    /// Event-loop threads multiplexing connections (0 is treated as 1).
    /// One loop rides hundreds of idle tenants; more loops spread
    /// *active* connections across cores (accepted sockets are routed
    /// round-robin).
    pub event_loop_threads: usize,
    /// Open-connection cap: at the cap the listener leaves the poll
    /// set, so further connects wait in the accept backlog until a
    /// connection closes (nothing is reset mid-handshake). Pair it
    /// with [`ServerConfig::idle_timeout`] when clients may linger or
    /// vanish without a FIN (NAT drops): with no timeout, idle
    /// connections hold their slots forever and a full cap silently
    /// parks every new connect in the backlog.
    pub max_connections: usize,
    /// Drop RPC connections idle (no bytes either way) longer than
    /// this. Subscriber streams are exempt — a caught-up subscriber on
    /// a quiet primary is legitimately silent. `None` (default) keeps
    /// idle connections forever, matching the old server.
    pub idle_timeout: Option<Duration>,
    /// Dispatches slower than this emit a rate-limited warn line (and
    /// always bump the `rpc_slow_requests_total` counter). The default
    /// reads the `HLL_SLOW_REQ_MS` env var (milliseconds); unset means
    /// no threshold and no tracing.
    pub slow_request_threshold: Option<Duration>,
    /// Kernel readiness backend for the event loops. The default
    /// (`Auto`) resolves to the best available for the platform (epoll
    /// on Linux, poll elsewhere), overridable at runtime with
    /// `HLL_POLLER=poll|epoll|kqueue`; an unavailable explicit choice
    /// falls back to the best available.
    pub poller_backend: PollerBackend,
    /// With more than one event loop, give every loop its own listener
    /// on the shared port via `SO_REUSEPORT` so the kernel shards
    /// accepts across loops (no cross-thread accept routing). Falls
    /// back to the single-listener + routing model where the raw bind
    /// fails or the platform lacks support. Default: on for Linux.
    pub reuseport: bool,
    /// Worker threads taking blocking work (`Snapshot` RPC file writes,
    /// subscriber full-sync image serialization) off the event loops;
    /// the loop halts only the requesting connection and answers on
    /// completion via its waker. 0 = serve those inline on the loop
    /// (the pre-pool behavior).
    pub worker_threads: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            snapshot_path: None,
            read_only: false,
            replication: None,
            sweeper: None,
            event_loop_threads: 1,
            max_connections: 4096,
            idle_timeout: None,
            slow_request_threshold: std::env::var("HLL_SLOW_REQ_MS")
                .ok()
                .and_then(|v| v.trim().parse::<u64>().ok())
                .map(Duration::from_millis),
            poller_backend: PollerBackend::Auto,
            reuseport: cfg!(target_os = "linux"),
            worker_threads: 1,
        }
    }
}

/// Point-in-time server counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ServerStatsSnapshot {
    /// Connections accepted since start.
    pub connections: u64,
    /// Connections currently open (gauge).
    pub connections_open: u64,
    /// High-water mark of simultaneously open connections.
    pub connections_peak: u64,
    /// Frames served (requests fully read, valid or not).
    pub frames: u64,
    /// Frames whose bytes arrived across more than one socket read —
    /// partial reads the incremental decoder resumed (nonzero under
    /// slow or trickling peers; the blocking server would have parked a
    /// thread for each).
    pub partial_frames_resumed: u64,
    /// Words ingested through `INSERT_BATCH`.
    pub words_ingested: u64,
    /// Requests answered with an `ERROR` frame.
    pub error_frames: u64,
    /// Background sweeper passes completed.
    pub sweeps: u64,
    /// Keys evicted by background sweeper passes.
    pub keys_swept: u64,
    /// `DELTA_BATCH` frames streamed to subscribers.
    pub delta_batches_sent: u64,
    /// `FULL_SYNC` frames streamed to subscribers (bootstraps plus
    /// stale-cursor fallbacks).
    pub full_syncs_sent: u64,
    /// Sketches accepted through `MERGE_SKETCH`.
    pub sketches_merged: u64,
    /// Keys evicted through the `Evict` RPC and ingest-piggybacked
    /// budget enforcement (the background sweeper's own evictions are
    /// `keys_swept`).
    pub keys_evicted: u64,
}

/// Serving counters. Every field is a [`MetricsRegistry`] handle, so
/// the same cells feed both [`SketchServer::stats`] and the
/// `MetricsDump` exposition — no double accounting. The handles deref
/// to `AtomicU64`, so hot-path sites use `fetch_add`/`fetch_max`
/// directly.
#[derive(Debug)]
struct ServerStats {
    connections: Counter,
    connections_open: Gauge,
    connections_peak: Gauge,
    frames: Counter,
    partial_frames_resumed: Counter,
    words_ingested: Counter,
    error_frames: Counter,
    sweeps: Counter,
    keys_swept: Counter,
    delta_batches_sent: Counter,
    full_syncs_sent: Counter,
    sketches_merged: Counter,
    keys_evicted: Counter,
}

impl ServerStats {
    fn register(m: &MetricsRegistry) -> Self {
        Self {
            connections: m.counter("server_connections_total", None),
            connections_open: m.gauge("server_connections_open", None),
            connections_peak: m.gauge("server_connections_peak", None),
            frames: m.counter("server_frames_total", None),
            partial_frames_resumed: m.counter("server_partial_frames_resumed_total", None),
            words_ingested: m.counter("server_words_ingested_total", None),
            error_frames: m.counter("server_error_frames_total", None),
            sweeps: m.counter("server_sweeps_total", None),
            keys_swept: m.counter("server_keys_swept_total", None),
            delta_batches_sent: m.counter("server_delta_batches_sent_total", None),
            full_syncs_sent: m.counter("server_full_syncs_sent_total", None),
            sketches_merged: m.counter("server_sketches_merged_total", None),
            keys_evicted: m.counter("server_keys_evicted_total", None),
        }
    }
}

/// Per-opcode RPC instrumentation: one latency histogram, payload-size
/// histogram, and request counter per request opcode, pre-registered at
/// server start so the dispatch path is a bare array index — no name
/// lookup, no lock.
#[derive(Debug)]
struct RpcMetrics {
    latency_ns: [Arc<LatencyHistogram>; REQUEST_OPCODE_MAX as usize],
    payload_bytes: [Arc<LatencyHistogram>; REQUEST_OPCODE_MAX as usize],
    total: [Counter; REQUEST_OPCODE_MAX as usize],
    slow_requests: Counter,
    /// Wall-clock ns of the last slow-request warn (rate limiting).
    last_slow_warn_ns: AtomicU64,
}

/// Minimum spacing between slow-request warn lines: the counter sees
/// every slow dispatch, the log sees at most ten per second.
const SLOW_WARN_EVERY_NS: u64 = 100_000_000;

impl RpcMetrics {
    fn register(m: &MetricsRegistry) -> Self {
        let op = |i: usize| Some(("op", request_opcode_name(i as u8 + 1).to_string()));
        Self {
            latency_ns: std::array::from_fn(|i| m.histogram("rpc_latency_ns", op(i))),
            payload_bytes: std::array::from_fn(|i| m.histogram("rpc_payload_bytes", op(i))),
            total: std::array::from_fn(|i| m.counter("rpc_total", op(i))),
            slow_requests: m.counter("rpc_slow_requests_total", None),
            last_slow_warn_ns: AtomicU64::new(0),
        }
    }

    /// Instrument slot for a request opcode (`None` for unknown bytes —
    /// those still answer a typed error, they just have no series).
    fn idx(opcode: u8) -> Option<usize> {
        (1..=REQUEST_OPCODE_MAX).contains(&opcode).then(|| (opcode - 1) as usize)
    }

    /// One dispatched frame: bump the per-opcode series and, past the
    /// configured threshold, the slow-request path (counter always;
    /// warn line, structured recorder event and black-box snapshot all
    /// rate-limited behind the same CAS).
    fn observe(
        &self,
        cfg: &ServerConfig,
        opcode: u8,
        payload: &[u8],
        elapsed: Duration,
        trace_id: u64,
    ) {
        let Some(i) = Self::idx(opcode) else { return };
        let elapsed_ns = elapsed.as_nanos().min(u64::MAX as u128) as u64;
        self.total[i].inc();
        self.payload_bytes[i].record(payload.len() as u64);
        self.latency_ns[i].record(elapsed_ns);
        let Some(threshold) = cfg.slow_request_threshold else { return };
        if elapsed < threshold {
            return;
        }
        self.slow_requests.inc();
        let now = crate::obs::unix_time_ns();
        let last = self.last_slow_warn_ns.load(Ordering::Relaxed);
        if now.saturating_sub(last) >= SLOW_WARN_EVERY_NS
            && self
                .last_slow_warn_ns
                .compare_exchange(last, now, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
        {
            // The one payload whose item count is knowable without a
            // full decode: INSERT_BATCH is key (8) + word count (4) + words.
            let words = if opcode == opcodes::INSERT_BATCH && payload.len() >= 12 {
                u32::from_le_bytes(payload[8..12].try_into().expect("4-byte slice")) as u64
            } else {
                0
            };
            // Structured half of the warn: an instant event marks the
            // slow dispatch in the ring (under its trace, if any), then
            // the black box freezes the ring — the offending span's
            // begin/end events were recorded before `observe` ran, so
            // the snapshot contains them.
            recorder::record(TraceEvent {
                ns: crate::obs::monotonic_ns(),
                trace_id,
                payload: elapsed_ns,
                stage: Stage::Dispatch as u8,
                kind: EventKind::Instant as u8,
            });
            recorder::note_anomaly(&format!(
                "slow request: op={} took {:.3}ms",
                request_opcode_name(opcode),
                elapsed_ns as f64 / 1e6
            ));
            crate::log_warn!(
                "server",
                "slow request: op={} words={} payload={}B took {:.3}ms (threshold {:.3}ms)",
                request_opcode_name(opcode),
                words,
                payload.len(),
                elapsed_ns as f64 / 1e6,
                threshold.as_secs_f64() * 1e3
            );
        }
    }
}

/// A blocking unit of work shipped to the worker pool.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Submission side of the worker pool. The `Mutex` serializes the
/// (rare) submits across loop threads; workers share the receiving end
/// behind their own lock.
#[derive(Debug)]
struct WorkerPool {
    tx: Mutex<mpsc::Sender<Job>>,
}

impl WorkerPool {
    /// `false` = the pool is gone (shutdown race); run the work inline.
    fn submit(&self, job: Job) -> bool {
        self.tx.lock().map(|tx| tx.send(job).is_ok()).unwrap_or(false)
    }
}

/// Result of an off-loop job, parked in the owning loop's completion
/// queue until its waker-roused tick applies it.
#[derive(Debug)]
struct Completion {
    /// Slot index of the requesting connection on its loop.
    conn_idx: usize,
    /// Admission generation of that connection when the job was
    /// submitted: a slot reused by a newer connection has a different
    /// generation, so a stale result is dropped instead of answering
    /// the wrong peer.
    gen: u64,
    kind: CompletionKind,
}

#[derive(Debug)]
enum CompletionKind {
    /// Queue this reply frame (the `Snapshot` RPC path).
    Reply(Response),
    /// A serialized registry image for a subscriber full sync; the loop
    /// thread applies the frame-cap check and cursor bookkeeping.
    FullSync { epoch: u64, cursor: u64, body: Vec<u8> },
}

#[derive(Debug)]
struct Shared {
    registry: Arc<SketchRegistry<u64>>,
    cfg: ServerConfig,
    stop: AtomicBool,
    stats: ServerStats,
    /// Every instrument this server exposes (stats handles, per-opcode
    /// RPC series, loop tick profiles, bridged registry/replication
    /// gauges). `MetricsDump` renders it. Bridge closures registered
    /// into it must never capture `Arc<Shared>` — that would cycle
    /// through this field and leak the server.
    metrics: Arc<MetricsRegistry>,
    /// Per-opcode dispatch instrumentation.
    rpc: RpcMetrics,
    /// Per-stage `stage_latency_ns{stage=...}` histograms fed by the
    /// serving-path [`Span`]s (decode, dispatch, shard ingest).
    timers: StageTimers,
    /// Highest cursor any subscriber has acked — the most-advanced
    /// follower, so the bridged lag gauges are a lower bound when
    /// several followers subscribe. Shared with the replication-lag
    /// `gauge_fn` closures (hence the `Arc`, see `metrics` above).
    acked_seq: Arc<AtomicU64>,
    /// Present iff this server is a replication primary.
    log: Option<Arc<ReplicationLog>>,
    /// One waker per event loop: the capture thread and shutdown kick
    /// every loop out of `poll` the moment there is work.
    wakers: Vec<Waker>,
    /// Present iff [`ServerConfig::worker_threads`] > 0: blocking work
    /// (snapshot writes, full-sync serialization) leaves the loops
    /// through here.
    workers: Option<WorkerPool>,
    /// One completion queue per event loop: worker threads park results
    /// here via [`Shared::deliver`], the owning loop drains its queue at
    /// the top of each tick.
    completions: Vec<Mutex<Vec<Completion>>>,
}

impl Shared {
    fn wake_all(&self) {
        for w in &self.wakers {
            w.wake();
        }
    }

    /// Park a finished off-loop job on its owning loop's completion
    /// queue, then kick that loop's waker so it applies the result
    /// within one syscall instead of one poll tick.
    fn deliver(&self, loop_idx: usize, done: Completion) {
        if let Some(q) = self.completions.get(loop_idx) {
            if let Ok(mut q) = q.lock() {
                q.push(done);
            }
        }
        if let Some(w) = self.wakers.get(loop_idx) {
            w.wake();
        }
    }
}

/// A running sketch server. Dropping it performs a full graceful
/// shutdown (stop accepting, drain the pollers, join the loop threads).
pub struct SketchServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    loop_joins: Vec<JoinHandle<()>>,
    /// Sweeper and replication-capture threads, joined on shutdown like
    /// the loop threads.
    maint_joins: Vec<JoinHandle<()>>,
}

impl SketchServer {
    /// Bind `addr` (use port 0 for an OS-assigned port) and start
    /// serving `registry`.
    pub fn start(
        addr: impl ToSocketAddrs,
        registry: Arc<SketchRegistry<u64>>,
        cfg: ServerConfig,
    ) -> io::Result<Self> {
        let threads = cfg.event_loop_threads.max(1);
        let (listeners, addr, sharded) = bind_listeners(addr, threads, cfg.reuseport)?;
        let worker_threads = cfg.worker_threads;
        // A replication primary needs dirty tracking on before any
        // subscriber can connect: every mutation then either lands in a
        // subscriber's bootstrap full sync (it ran before the loops
        // existed) or in a sealed delta batch — never in neither.
        // Enabled only after the fallible bind, so a failed start does
        // not leave the shared registry accumulating dirty keys that
        // nothing will ever drain.
        let log = cfg.replication.as_ref().map(|_| {
            registry.enable_dirty_tracking();
            Arc::new(ReplicationLog::new())
        });
        let mut wakers = Vec::with_capacity(threads);
        let mut wake_rxs = Vec::with_capacity(threads);
        for _ in 0..threads {
            let (w, r) = reactor::waker_pair()?;
            wakers.push(w);
            wake_rxs.push(r);
        }
        let metrics = MetricsRegistry::shared();
        let acked_seq = Arc::new(AtomicU64::new(0));
        register_bridges(&metrics, &registry, log.as_ref(), &acked_seq);
        // The flight recorder is process-global and off by default (one
        // relaxed load for library users); a serving process wants it
        // on. Never disabled on shutdown — another server in the same
        // process (tests, embedded replicas) may still be recording.
        recorder::set_enabled(true);
        let mut worker_rx = None;
        let workers = (worker_threads > 0).then(|| {
            let (tx, rx) = mpsc::channel::<Job>();
            worker_rx = Some(Arc::new(Mutex::new(rx)));
            WorkerPool { tx: Mutex::new(tx) }
        });
        let shared = Arc::new(Shared {
            registry,
            cfg,
            stop: AtomicBool::new(false),
            stats: ServerStats::register(&metrics),
            rpc: RpcMetrics::register(&metrics),
            timers: StageTimers::register(&metrics),
            metrics,
            acked_seq,
            log,
            wakers,
            workers,
            completions: (0..threads).map(|_| Mutex::new(Vec::new())).collect(),
        });
        let mut routes = Vec::with_capacity(threads);
        let mut intakes = Vec::with_capacity(threads);
        for _ in 0..threads {
            let (tx, rx) = mpsc::channel();
            routes.push(tx);
            intakes.push(rx);
        }
        let mut maint_joins = Vec::new();
        if let (Some(log), Some(rcfg)) = (&shared.log, &shared.cfg.replication) {
            let capture_shared = shared.clone();
            let capture_log = log.clone();
            let capture_cfg = rcfg.clone();
            maint_joins.push(
                std::thread::Builder::new()
                    .name("sketch-server-capture".into())
                    .spawn(move || capture_loop(capture_shared, capture_log, capture_cfg))?,
            );
        }
        if let Some(scfg) = &shared.cfg.sweeper {
            let sweep_shared = shared.clone();
            let sweep_cfg = scfg.clone();
            maint_joins.push(
                std::thread::Builder::new()
                    .name("sketch-server-sweeper".into())
                    .spawn(move || sweeper_loop(sweep_shared, sweep_cfg))?,
            );
        }
        if let Some(rx) = worker_rx {
            for w in 0..worker_threads {
                let worker_shared = shared.clone();
                let worker_rx = rx.clone();
                maint_joins.push(
                    std::thread::Builder::new()
                        .name(format!("sketch-server-worker-{w}"))
                        .spawn(move || worker_loop(worker_shared, worker_rx))?,
                );
            }
        }
        shared
            .metrics
            .gauge("server_accept_sharded", None)
            .store(sharded as u64, Ordering::Relaxed);
        let mut loop_joins = Vec::with_capacity(threads);
        for (i, ((wake_rx, intake), listener)) in
            wake_rxs.into_iter().zip(intakes).zip(listeners).enumerate()
        {
            // Create the poller here (not in the loop thread) so the
            // tick profile registers under the backend actually in use,
            // including any init-failure fallback.
            let poller = Poller::with_backend(shared.cfg.poller_backend)
                .unwrap_or_else(|_| Poller::new());
            shared
                .metrics
                .gauge(
                    "server_poller_loops",
                    Some(("backend", poller.backend().label().to_string())),
                )
                .fetch_add(1, Ordering::Relaxed);
            let parts = LoopParts {
                loop_idx: i,
                // Sharded: every loop owns a REUSEPORT listener and
                // admits locally. Fallback: loop 0 owns the single
                // listener and routes accepted sockets round-robin
                // across every loop (itself included).
                listener,
                wake_rx,
                intake,
                routes: if sharded || i != 0 { Vec::new() } else { routes.clone() },
                profile: TickProfile::register(&shared.metrics, i, poller.backend()),
                poller,
            };
            let loop_shared = shared.clone();
            loop_joins.push(
                std::thread::Builder::new()
                    .name(format!("sketch-server-loop-{i}"))
                    .spawn(move || event_loop(loop_shared, parts))?,
            );
        }
        crate::log_debug!(
            "server",
            "listening on {addr} ({threads} event loop thread(s), accepts {})",
            if sharded { "sharded via SO_REUSEPORT" } else { "routed from loop 0" }
        );
        Ok(Self { addr, shared, loop_joins, maint_joins })
    }

    /// The bound address (with the real port when started on port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The registry this server fronts.
    pub fn registry(&self) -> &Arc<SketchRegistry<u64>> {
        &self.shared.registry
    }

    /// Aggregate serving counters.
    pub fn stats(&self) -> ServerStatsSnapshot {
        let s = &self.shared.stats;
        ServerStatsSnapshot {
            connections: s.connections.load(Ordering::Relaxed),
            connections_open: s.connections_open.load(Ordering::Relaxed),
            connections_peak: s.connections_peak.load(Ordering::Relaxed),
            frames: s.frames.load(Ordering::Relaxed),
            partial_frames_resumed: s.partial_frames_resumed.load(Ordering::Relaxed),
            words_ingested: s.words_ingested.load(Ordering::Relaxed),
            error_frames: s.error_frames.load(Ordering::Relaxed),
            sweeps: s.sweeps.load(Ordering::Relaxed),
            keys_swept: s.keys_swept.load(Ordering::Relaxed),
            delta_batches_sent: s.delta_batches_sent.load(Ordering::Relaxed),
            full_syncs_sent: s.full_syncs_sent.load(Ordering::Relaxed),
            sketches_merged: s.sketches_merged.load(Ordering::Relaxed),
            keys_evicted: s.keys_evicted.load(Ordering::Relaxed),
        }
    }

    /// The server's instrument registry: per-opcode RPC series, loop
    /// tick profiles, bridged registry/replication gauges and the
    /// serving counters. Benches and tests fetch live handles from it
    /// (same `(name, label)` returns the same cell).
    pub fn metrics(&self) -> &Arc<MetricsRegistry> {
        &self.shared.metrics
    }

    /// Render the metrics exposition text (same bytes the
    /// `MetricsDump` RPC answers) without a connection — the in-process
    /// side channel for embedding servers.
    pub fn metrics_text(&self) -> String {
        self.shared.metrics.render()
    }

    /// The replication log this primary seals delta batches into
    /// (`None` unless started with [`ServerConfig::replication`]).
    /// Tests and benches use it to force a synchronous capture
    /// ([`ReplicationLog::capture`]) and to read the latest sealed seq.
    pub fn replication_log(&self) -> Option<&Arc<ReplicationLog>> {
        self.shared.log.as_ref()
    }

    /// Graceful shutdown: stop accepting, wake and join every event
    /// loop (queued replies get a best-effort flush). Also runs on drop.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        // The wakers kick every loop out of `poll` immediately; the
        // maintenance threads poll the flag on short sleeps.
        self.shared.wake_all();
        for join in self.loop_joins.drain(..) {
            let _ = join.join();
        }
        for join in self.maint_joins.drain(..) {
            let _ = join.join();
        }
    }
}

impl Drop for SketchServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Bind the per-loop listener set. With several loops and `reuseport`
/// requested, every loop gets its own `SO_REUSEPORT` listener on the
/// shared port (`sharded = true`: the kernel spreads accepts, no
/// cross-thread routing). Anywhere that can't work — one loop, the
/// option off, a non-Linux platform, or the raw bind failing — loop 0
/// gets the one `std` listener and the caller keeps the routing model.
fn bind_listeners(
    addr: impl ToSocketAddrs,
    threads: usize,
    want_reuseport: bool,
) -> io::Result<(Vec<Option<TcpListener>>, SocketAddr, bool)> {
    if threads > 1 && want_reuseport {
        // `&addr`: keep the original for the fallback bind below.
        if let Ok(group) = reuseport::bind_group(&addr, threads) {
            let bound = group[0].local_addr()?;
            // Group sockets are born nonblocking (SOCK_NONBLOCK).
            return Ok((group.into_iter().map(Some).collect(), bound, true));
        }
    }
    let listener = TcpListener::bind(&addr)?;
    let bound = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let mut listeners: Vec<Option<TcpListener>> = Vec::with_capacity(threads);
    listeners.push(Some(listener));
    listeners.resize_with(threads, || None);
    Ok((listeners, bound, false))
}

/// Bridge pre-existing subsystem stats into the metrics registry as
/// scrape-time gauges, so the exposition carries per-tier key counts,
/// resident bytes and replication lag without a second set of counters
/// to keep in sync. The closures capture only the subsystem `Arc`s
/// (never `Shared`, which owns the registry — see [`Shared::metrics`]).
fn register_bridges(
    metrics: &MetricsRegistry,
    registry: &Arc<SketchRegistry<u64>>,
    log: Option<&Arc<ReplicationLog>>,
    acked_seq: &Arc<AtomicU64>,
) {
    let tier = |t: &'static str| Some(("tier", t.to_string()));
    let r = registry.clone();
    metrics.gauge_fn("registry_keys", None, move || r.stats().keys() as f64);
    let r = registry.clone();
    metrics.gauge_fn("registry_tier_keys", tier("sparse"), move || {
        r.stats().sparse_keys() as f64
    });
    let r = registry.clone();
    metrics.gauge_fn("registry_tier_keys", tier("packed"), move || {
        r.stats().packed_keys() as f64
    });
    let r = registry.clone();
    metrics.gauge_fn("registry_tier_keys", tier("dense"), move || {
        r.stats().dense_keys() as f64
    });
    let r = registry.clone();
    metrics.gauge_fn("registry_memory_bytes", None, move || r.stats().memory_bytes() as f64);
    let r = registry.clone();
    metrics.gauge_fn("registry_words_total", None, move || r.stats().words() as f64);
    let Some(log) = log else { return };
    let l = log.clone();
    metrics.gauge_fn("replication_latest_seq", None, move || l.latest_seq() as f64);
    let l = log.clone();
    metrics.gauge_fn("replication_retained_bytes", None, move || {
        l.stats().retained_bytes as f64
    });
    let l = log.clone();
    let acked = acked_seq.clone();
    metrics.gauge_fn("replication_lag_entries", None, move || {
        l.lag_after(acked.load(Ordering::Relaxed)).0 as f64
    });
    let l = log.clone();
    let acked = acked_seq.clone();
    metrics.gauge_fn("replication_lag_bytes", None, move || {
        l.lag_after(acked.load(Ordering::Relaxed)).1 as f64
    });
}

// ---------------------------------------------------------------------------
// The event loop
// ---------------------------------------------------------------------------

/// What one connection is, beyond its socket: the serving mode.
#[derive(Debug)]
enum ConnMode {
    /// Request/response RPC serving.
    Rpc,
    /// A replication stream (`SUBSCRIBE` flipped it): a nonblocking
    /// outbound pump over the sealed batch log, bounded by the unacked
    /// window, reading only `REPLICA_ACK` frames back.
    Subscriber { sent: u64, acked: u64, wire: u8, ack_window: u64 },
}

/// One connection's full state machine.
#[derive(Debug)]
struct Conn {
    stream: TcpStream,
    decoder: FrameDecoder,
    encoder: FrameEncoder,
    mode: ConnMode,
    last_activity: Instant,
    /// Stop reading/dispatching; flush the encoder, then close (the
    /// "answer the typed error, then drop" path).
    closing: bool,
    /// The peer half-closed (FIN): no more bytes will arrive, but
    /// requests already buffered in the decoder are still served and
    /// their replies flushed — the connection closes once the decoder
    /// has no work left and the encoder is drained.
    read_eof: bool,
    /// Remove now (peer gone, fatal IO error, idle timeout).
    dead: bool,
    /// Which event loop owns this connection (completions route back
    /// here).
    loop_idx: usize,
    /// This connection's index in its loop's `conns` vec.
    slot: usize,
    /// Admission generation: paired with `slot` to detect a completion
    /// addressed to a connection that died and had its slot reused.
    gen: u64,
    /// An off-loop job is in flight for this connection: frame
    /// dispatching and subscriber pumping halt (preserving reply order),
    /// and the reaper leaves the slot alone, until the completion lands.
    awaiting: bool,
}

/// Per-loop plumbing handed to each loop thread.
struct LoopParts {
    /// This loop's index (completion routing, waker addressing).
    loop_idx: usize,
    /// Present on every loop when accepts are REUSEPORT-sharded; on the
    /// accepting loop (loop 0) only otherwise.
    listener: Option<TcpListener>,
    wake_rx: WakeRx,
    /// Connections routed to this loop by the accepting loop.
    intake: mpsc::Receiver<TcpStream>,
    /// Round-robin routing targets (unsharded accepting loop only;
    /// empty elsewhere — an empty set means "admit locally").
    routes: Vec<mpsc::Sender<TcpStream>>,
    /// This loop's tick instrumentation (poll-wait vs dispatch time,
    /// ready events per tick, saturation gauge), labeled per loop and
    /// per backend.
    profile: TickProfile,
    /// The readiness backend, built in `start` so the profile's backend
    /// label matches reality.
    poller: Poller,
}

fn event_loop(shared: Arc<Shared>, mut parts: LoopParts) {
    let mut conns: Vec<Option<Conn>> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    let mut next_route = 0usize;
    // Admission generations (see [`Conn::gen`]); per-loop, never 0.
    let mut next_gen: u64 = 1;
    let mut read_buf = vec![0u8; 16 * 1024];
    // Set after a non-WouldBlock accept failure (EMFILE and friends):
    // the listener leaves the interest set until this passes, so the
    // backlog's level-triggered readability cannot hot-spin the loop —
    // and no connection pays a sleep for it.
    let mut accept_backoff: Option<Instant> = None;
    // Tick profiling: everything between two polls is "work", the poll
    // itself is "wait". The first tick's work window opens here.
    let mut work_started = Instant::now();

    loop {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        // (1) Adopt connections the accepting loop routed here.
        while let Ok(stream) = parts.intake.try_recv() {
            admit(&mut conns, &mut free, stream, parts.loop_idx, &mut next_gen);
        }
        // (1b) Land worker-pool results addressed to this loop. Swap the
        // queue out under the lock, apply outside it — a completion's
        // `process_frames` can submit the next job, which could deliver
        // (other workers) while we're still applying.
        let done: Vec<Completion> = shared
            .completions
            .get(parts.loop_idx)
            .and_then(|q| q.lock().ok().map(|mut q| std::mem::take(&mut *q)))
            .unwrap_or_default();
        for c in done {
            apply_completion(&mut conns, &shared, c);
        }
        // (2) Pump subscriber streams: fill encoders from the sealed
        // log up to the ack window / byte budget. Runs every tick and
        // after every capture wakeup; cheap (one log read) when caught
        // up.
        if let Some(log) = shared.log.clone() {
            for slot in conns.iter_mut() {
                if let Some(conn) = slot {
                    if !conn.closing
                        && !conn.dead
                        && matches!(conn.mode, ConnMode::Subscriber { .. })
                    {
                        pump_subscriber(conn, &shared, &log);
                    }
                }
            }
        }
        // (3) Flush pending output; resume frames the decoder buffered
        // while reads were paused, now that replies drained.
        for slot in conns.iter_mut() {
            if let Some(conn) = slot {
                flush_and_resume(conn, &shared);
            }
        }
        // (4) Reap closed connections; sweep idle ones.
        for (idx, slot) in conns.iter_mut().enumerate() {
            let Some(conn) = slot else { continue };
            // Non-closing subscribers are exempt: a caught-up stream on
            // a quiet primary is legitimately silent. `closing`
            // connections of either mode are not — a peer that never
            // drains its final error frame would otherwise pin the
            // slot forever.
            // A connection waiting on an off-loop job is not idle and
            // is still owed its reply: the sweep and the half-close
            // reap both stand down until the completion lands (stale
            // completions are generation-checked anyway, so this is
            // about answering the peer, not memory safety).
            if let Some(t) = shared.cfg.idle_timeout {
                if (matches!(conn.mode, ConnMode::Rpc) || conn.closing)
                    && !conn.awaiting
                    && conn.last_activity.elapsed() > t
                {
                    conn.dead = true;
                }
            }
            let half_closed_done = conn.read_eof && !conn.decoder.has_work() && !conn.awaiting;
            if conn.dead || ((conn.closing || half_closed_done) && conn.encoder.is_empty()) {
                *slot = None;
                free.push(idx);
                shared.stats.connections_open.fetch_sub(1, Ordering::Relaxed);
            }
        }
        // (5) Rebuild the interest set: this is where backpressure
        // *flips interest* — no read interest past the reply-buffer
        // threshold, write interest exactly while bytes are queued.
        parts.poller.clear();
        parts.poller.register(parts.wake_rx.as_raw_fd(), TOKEN_WAKER, true, false);
        if accept_backoff.is_some_and(|until| Instant::now() >= until) {
            accept_backoff = None;
        }
        if let Some(listener) = &parts.listener {
            let open = shared.stats.connections_open.load(Ordering::Relaxed) as usize;
            if open < shared.cfg.max_connections && accept_backoff.is_none() {
                parts.poller.register(listener.as_raw_fd(), TOKEN_LISTENER, true, false);
            }
        }
        for (idx, slot) in conns.iter().enumerate() {
            let Some(conn) = slot else { continue };
            // No read interest after the peer's FIN: the socket would
            // report readable-EOF every tick forever.
            let readable = !conn.closing
                && !conn.read_eof
                && (matches!(conn.mode, ConnMode::Subscriber { .. })
                    || conn.encoder.pending() < READ_PAUSE_BYTES);
            let writable = !conn.encoder.is_empty();
            parts.poller.register(conn.stream.as_raw_fd(), idx, readable, writable);
        }
        // (6) Wait for readiness (or the tick).
        let poll_started = Instant::now();
        let polled = parts.poller.poll(Some(POLL_TICK));
        let waited = poll_started.elapsed();
        if polled.is_err() {
            // Transient poll failure: back off instead of hot-spinning.
            std::thread::sleep(Duration::from_millis(5));
            continue;
        }
        // (7) Handle events. Level-triggered semantics: anything not
        // finished this pass is re-reported next poll.
        let ready: Vec<reactor::Readiness> = parts.poller.ready().collect();
        parts.profile.tick(
            poll_started.duration_since(work_started),
            waited,
            ready.len(),
        );
        work_started = Instant::now();
        for r in ready {
            match r.token {
                TOKEN_WAKER => parts.wake_rx.drain(),
                TOKEN_LISTENER => {
                    if !accept_ready(
                        &shared,
                        &parts,
                        &mut next_route,
                        &mut conns,
                        &mut free,
                        &mut next_gen,
                    ) {
                        accept_backoff = Some(Instant::now() + Duration::from_millis(20));
                    }
                }
                idx => {
                    let Some(conn) = conns.get_mut(idx).and_then(|s| s.as_mut()) else {
                        continue;
                    };
                    if r.invalid {
                        conn.dead = true;
                        continue;
                    }
                    if r.readable {
                        on_readable(conn, &shared, &mut read_buf);
                    }
                    if r.writable {
                        flush_and_resume(conn, &shared);
                    }
                }
            }
        }
    }

    // Shutdown: drain the poller's connections — best-effort flush of
    // queued replies (sockets are nonblocking; a full buffer just drops
    // the rest), then close everything. Sockets routed here but not yet
    // adopted still count in the open gauge: drain them too, or the
    // gauge reads phantom connections forever after shutdown.
    for slot in conns.iter_mut() {
        if let Some(mut conn) = slot.take() {
            let _ = conn.encoder.write_to(&mut conn.stream);
            shared.stats.connections_open.fetch_sub(1, Ordering::Relaxed);
        }
    }
    while parts.intake.try_recv().is_ok() {
        shared.stats.connections_open.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Take ownership of an accepted socket as a fresh RPC-mode connection
/// on this loop.
fn admit(
    conns: &mut Vec<Option<Conn>>,
    free: &mut Vec<usize>,
    stream: TcpStream,
    loop_idx: usize,
    next_gen: &mut u64,
) {
    let _ = stream.set_nonblocking(true);
    let _ = stream.set_nodelay(true);
    let gen = *next_gen;
    *next_gen = next_gen.wrapping_add(1);
    let slot = free.pop().unwrap_or(conns.len());
    let conn = Conn {
        stream,
        decoder: FrameDecoder::new(),
        encoder: FrameEncoder::new(),
        mode: ConnMode::Rpc,
        last_activity: Instant::now(),
        closing: false,
        read_eof: false,
        dead: false,
        loop_idx,
        slot,
        gen,
        awaiting: false,
    };
    if slot == conns.len() {
        conns.push(Some(conn));
    } else {
        conns[slot] = Some(conn);
    }
}

/// Accept everything pending (up to the connection cap). With REUSEPORT
/// sharding (`routes` empty) each socket is admitted locally — the
/// kernel already chose this loop; otherwise sockets are routed
/// round-robin across the loops, waking the target. Returns `false` on
/// a persistent accept failure (EMFILE being the classic): the failed
/// connection stays in the backlog keeping the listener level-triggered
/// readable, so the caller must take the listener out of the interest
/// set briefly or the loop hot-spins.
fn accept_ready(
    shared: &Shared,
    parts: &LoopParts,
    next_route: &mut usize,
    conns: &mut Vec<Option<Conn>>,
    free: &mut Vec<usize>,
    next_gen: &mut u64,
) -> bool {
    let Some(listener) = &parts.listener else { return true };
    loop {
        // No new work once shutdown began — a socket routed to a loop
        // that already exited would leak its slot in the open gauge.
        if shared.stop.load(Ordering::SeqCst) {
            return true;
        }
        let open = shared.stats.connections_open.load(Ordering::Relaxed) as usize;
        if open >= shared.cfg.max_connections {
            return true;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                shared.stats.connections.fetch_add(1, Ordering::Relaxed);
                let now_open = shared.stats.connections_open.fetch_add(1, Ordering::Relaxed) + 1;
                shared.stats.connections_peak.fetch_max(now_open, Ordering::Relaxed);
                if parts.routes.is_empty() {
                    admit(conns, free, stream, parts.loop_idx, next_gen);
                    continue;
                }
                let target = *next_route % parts.routes.len();
                *next_route = next_route.wrapping_add(1);
                if parts.routes[target].send(stream).is_ok() {
                    shared.wakers[target].wake();
                } else {
                    shared.stats.connections_open.fetch_sub(1, Ordering::Relaxed);
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return true,
            Err(_) => return false,
        }
    }
}

/// Readable event: pull whatever the socket holds into the decoder
/// (bounded per burst for fairness), then dispatch the complete frames.
fn on_readable(conn: &mut Conn, shared: &Arc<Shared>, buf: &mut [u8]) {
    let mut eof = false;
    loop {
        match conn.stream.read(buf) {
            Ok(0) => {
                eof = true;
                break;
            }
            Ok(n) => {
                conn.decoder.extend(&buf[..n]);
                conn.last_activity = Instant::now();
                if conn.decoder.buffered() >= READ_BURST_BYTES || n < buf.len() {
                    break;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.dead = true;
                return;
            }
        }
    }
    process_frames(conn, shared);
    if eof {
        match conn.mode {
            // Half-close: requests pipelined before the FIN keep being
            // served (the reap waits until the decoder has no work and
            // the encoder drained — even across backpressure pauses).
            // A frame cut off mid-stream simply never completes: same
            // silent close as the blocking server.
            ConnMode::Rpc => conn.read_eof = true,
            // A subscriber that can never ack again is useless: flush
            // what's queued and drop, like the old stream loop's
            // immediate return on EOF.
            ConnMode::Subscriber { .. } => conn.closing = true,
        }
    }
}

/// Queue one reply frame, counting `error_frames` at this single choke
/// point. Every reply path must come through here: the old per-site
/// `fetch_add`s drifted (replies built outside `handle_rpc_frame` —
/// full-sync overflows, subscriber-pump failures — each needed their
/// own bump, and adding a site silently under-counted until someone
/// noticed).
fn queue_reply(conn: &mut Conn, shared: &Shared, resp: Response) {
    if let Response::Error { code, .. } = &resp {
        shared.stats.error_frames.fetch_add(1, Ordering::Relaxed);
        // Typed errors are anomalies: freeze the flight recorder's ring
        // into the black box so the events leading up to the error
        // survive the ring overwriting them. Bounded (the black box
        // drops its oldest entry), and skipped entirely while the
        // recorder is off — `note_anomaly` allocates.
        if recorder::enabled() {
            recorder::note_anomaly(&format!("error reply: {code:?}"));
        }
    }
    conn.encoder.push(resp.encode());
}

/// Dispatch every complete frame the decoder holds, honoring the
/// backpressure pause (RPC mode) and the closing latch. Also rolls the
/// decoder's resumed-frame count into the server stats, and times each
/// frame from dispatch start to reply queued for the per-opcode
/// latency series.
fn process_frames(conn: &mut Conn, shared: &Arc<Shared>) {
    loop {
        // `awaiting`: an off-loop job owns the next reply slot; frames
        // behind it stay buffered so pipelined replies keep their order.
        if conn.closing || conn.dead || conn.awaiting {
            break;
        }
        if matches!(conn.mode, ConnMode::Rpc) && conn.encoder.pending() >= READ_PAUSE_BYTES {
            // Reply buffer full: leave the remaining frames in the
            // decoder; `flush_and_resume` picks them back up once the
            // peer drains replies.
            break;
        }
        let (opcode, payload) = match conn.decoder.next_frame() {
            Ok(Some(frame)) => frame,
            Ok(None) => break,
            Err(e) => {
                // Framing is broken; resync is impossible. Answer once,
                // then drop the connection (after the flush).
                queue_reply(
                    conn,
                    shared,
                    Response::Error { code: ErrorCode::Malformed, message: e.to_string() },
                );
                conn.closing = true;
                break;
            }
        };
        shared.stats.frames.fetch_add(1, Ordering::Relaxed);
        // Peel the optional trailing trace context before the strict
        // request decode sees the payload; non-matching payloads pass
        // through byte-identical (trace_id 0 = untraced).
        let (body, trace_ctx) = split_trace_ctx(opcode, &payload);
        let trace_id = trace_ctx.unwrap_or(0);
        let dispatched = Instant::now();
        match conn.mode {
            ConnMode::Rpc => handle_rpc_frame(conn, shared, opcode, body, trace_id),
            ConnMode::Subscriber { .. } => {
                handle_subscriber_frame(conn, shared, opcode, &payload)
            }
        }
        shared.rpc.observe(&shared.cfg, opcode, &payload, dispatched.elapsed(), trace_id);
    }
    shared
        .stats
        .partial_frames_resumed
        .fetch_add(conn.decoder.take_resumed(), Ordering::Relaxed);
}

/// One complete frame on an RPC-mode connection: decode, dispatch,
/// queue the reply — or flip into a subscriber stream on `SUBSCRIBE`.
/// `payload` arrives with any trace context already peeled off;
/// `trace_id` is 0 for untraced requests.
fn handle_rpc_frame(
    conn: &mut Conn,
    shared: &Arc<Shared>,
    opcode: u8,
    payload: &[u8],
    trace_id: u64,
) {
    let decoded = {
        let _span = Span::enter_timed(Stage::Decode, trace_id, shared.timers.timer(Stage::Decode))
            .with_payload(payload.len() as u64);
        Request::decode(opcode, payload)
    };
    let resp = match decoded {
        Ok(Request::Subscribe { epoch, cursor, wire }) => match shared.log.clone() {
            Some(log) => {
                // The connection becomes a replication stream and never
                // returns to request/response serving. Bootstrap
                // (cursor 0 = "I have nothing") always full-syncs: the
                // registry may predate the log. So does a cursor issued
                // by a *different* log incarnation — a restarted
                // primary resets seq numbering, and without the epoch
                // check an old cursor could alias into the new log's
                // range and silently skip its early batches.
                let ack_window =
                    shared.cfg.replication.as_ref().map(|r| r.ack_window).unwrap_or(64);
                conn.mode = ConnMode::Subscriber { sent: cursor, acked: cursor, wire, ack_window };
                if (cursor == 0 || epoch != log.epoch()) && !push_full_sync(conn, shared, &log) {
                    return;
                }
                pump_subscriber(conn, shared, &log);
                return;
            }
            None => Response::Error {
                code: ErrorCode::Unsupported,
                message: "server is not a replication primary".into(),
            },
        },
        Ok(Request::ReplicaAck { .. }) => Response::Error {
            code: ErrorCode::Malformed,
            message: "ReplicaAck outside an active subscription".into(),
        },
        // A snapshot the server can actually take blocks on file IO for
        // the whole registry: ship it to the worker pool and answer on
        // completion. Requests it would *reject* (read-only, no path)
        // still answer inline through `dispatch` below.
        Ok(Request::Snapshot)
            if !shared.cfg.read_only
                && shared.cfg.snapshot_path.is_some()
                && shared.workers.is_some() =>
        {
            if submit_snapshot_job(conn, shared, trace_id) {
                return;
            }
            // Pool refused (shutdown race): serve it inline after all.
            dispatch(Request::Snapshot, shared, trace_id)
        }
        Ok(req) => dispatch(req, shared, trace_id),
        Err(e) => Response::Error { code: ErrorCode::Malformed, message: e.to_string() },
    };
    queue_reply(conn, shared, resp);
}

/// One complete frame on a subscriber stream: only `REPLICA_ACK` is
/// valid; an ack slides the window and re-pumps.
fn handle_subscriber_frame(conn: &mut Conn, shared: &Arc<Shared>, opcode: u8, payload: &[u8]) {
    match Request::decode(opcode, payload) {
        Ok(Request::ReplicaAck { cursor }) => {
            if let ConnMode::Subscriber { sent, acked, .. } = &mut conn.mode {
                // Clamp to what was actually sent: a buggy follower
                // cannot push the window past reality.
                *acked = (*acked).max(cursor.min(*sent));
                // Feed the bridged replication-lag gauges: lag is
                // measured from the most-advanced follower's ack.
                shared.acked_seq.fetch_max(*acked, Ordering::Relaxed);
            }
            if let Some(log) = shared.log.clone() {
                pump_subscriber(conn, shared, &log);
            }
        }
        _ => {
            queue_reply(
                conn,
                shared,
                Response::Error {
                    code: ErrorCode::Malformed,
                    message: "only ReplicaAck frames are valid on a subscription stream".into(),
                },
            );
            conn.closing = true;
        }
    }
}

/// Queue a complete registry image for a subscriber whose cursor the
/// log cannot serve (bootstrap, cross-epoch, or fell behind retention).
/// The cursor is read *before* the export: anything ingested in between
/// lands either in the image (a harmless duplicate under max-merge) or
/// in a batch with seq > cursor that pumps right after. Returns `false`
/// when the subscription is terminally broken (typed error queued,
/// connection closing).
///
/// With a worker pool, the image serialization — O(keys × registers),
/// the largest single stall an event loop could take — runs off-loop:
/// the connection is flagged `awaiting` (which halts its pump and
/// dispatch) and the loop finishes the job in [`apply_completion`].
fn push_full_sync(conn: &mut Conn, shared: &Arc<Shared>, log: &ReplicationLog) -> bool {
    if conn.awaiting {
        // An image is already being built for this connection.
        return true;
    }
    if !matches!(conn.mode, ConnMode::Subscriber { .. }) {
        return false;
    }
    if let Some(workers) = &shared.workers {
        let job_shared = shared.clone();
        let (loop_idx, slot, gen) = (conn.loop_idx, conn.slot, conn.gen);
        let submitted = workers.submit(Box::new(move || {
            let Some(log) = job_shared.log.clone() else { return };
            // Same ordering as the inline path: cursor before export.
            let epoch = log.epoch();
            let cursor = log.latest_seq();
            let body = snapshot::snapshot_to_vec(&job_shared.registry);
            job_shared.deliver(
                loop_idx,
                Completion {
                    conn_idx: slot,
                    gen,
                    kind: CompletionKind::FullSync { epoch, cursor, body },
                },
            );
        }));
        if submitted {
            conn.awaiting = true;
            return true;
        }
        // Pool refused (shutdown race): fall through to the inline path.
    }
    let ConnMode::Subscriber { sent, acked, .. } = &mut conn.mode else { return false };
    let cursor = log.latest_seq();
    let body = snapshot::snapshot_to_vec(&shared.registry);
    // A FULL_SYNC payload is epoch (8) + cursor (8) + len (4) + body.
    if body.len() as u64 + 20 > MAX_PAYLOAD as u64 {
        queue_reply(
            conn,
            shared,
            Response::Error {
                code: ErrorCode::Internal,
                message: format!(
                    "registry image of {} bytes exceeds the in-band full-sync frame cap; \
                     bootstrap this follower from a snapshot file",
                    body.len()
                ),
            },
        );
        conn.closing = true;
        return false;
    }
    conn.encoder.push(Response::FullSync { epoch: log.epoch(), cursor, body }.encode());
    shared.stats.full_syncs_sent.fetch_add(1, Ordering::Relaxed);
    *sent = cursor;
    *acked = cursor;
    true
}

/// Fill a subscriber's encoder from the sealed batch log: ship
/// everything past its position, bounded by the unacked window (slow
/// followers exert backpressure here) and a queued-byte budget (the
/// encoder never balloons to `ack_window × batch` bytes). Stale cursors
/// fall back to a full sync mid-stream.
fn pump_subscriber(conn: &mut Conn, shared: &Arc<Shared>, log: &Arc<ReplicationLog>) {
    loop {
        // `awaiting` also catches the async full-sync: `push_full_sync`
        // below returns `true` after merely *submitting* the image job,
        // and without this gate the `Stale` arm would re-submit forever.
        if conn.closing || conn.dead || conn.awaiting {
            return;
        }
        let ConnMode::Subscriber { sent, acked, wire, ack_window } = &conn.mode else { return };
        if sent.saturating_sub(*acked) >= *ack_window
            || conn.encoder.pending() >= SUB_PUMP_TARGET
        {
            return;
        }
        match log.read_after(*sent) {
            LogRead::Batch(batch) => {
                let Some(frame) = encode_batch_for_wire(&batch, *wire) else {
                    // Only legacy renderings can overflow; a v2
                    // follower cannot take this batch in any form, and
                    // Internal is in its terminal-halt set.
                    queue_reply(
                        conn,
                        shared,
                        Response::Error {
                            code: ErrorCode::Internal,
                            message: format!(
                                "batch {} inflates past the legacy frame cap; upgrade the \
                                 follower to delta wire v3 or bootstrap it from a snapshot",
                                batch.seq
                            ),
                        },
                    );
                    conn.closing = true;
                    return;
                };
                conn.encoder.push(frame);
                shared.stats.delta_batches_sent.fetch_add(1, Ordering::Relaxed);
                if let ConnMode::Subscriber { sent, .. } = &mut conn.mode {
                    *sent = batch.seq;
                }
            }
            LogRead::CaughtUp => return,
            LogRead::Stale => {
                // Fell behind retention (or resumed with a cursor from
                // a previous primary incarnation): resync.
                if !push_full_sync(conn, shared, log) {
                    return;
                }
            }
        }
    }
}

/// Nonblocking flush of queued replies; once the buffer drops below the
/// pause threshold, frames the decoder buffered during the pause are
/// served (the read-interest flip's other half). The flush is vectored:
/// every queued frame gathers into `writev` batches, so a pipelined
/// burst of small replies drains in one syscall instead of one each.
fn flush_and_resume(conn: &mut Conn, shared: &Arc<Shared>) {
    if conn.dead {
        return;
    }
    if !conn.encoder.is_empty() {
        let before = conn.encoder.pending();
        let fd = conn.stream.as_raw_fd();
        match conn.encoder.write_vectored_to(fd) {
            Ok(_) => {
                // Any byte accepted = the peer is draining: liveness
                // for the idle sweep (a backpressured connection
                // reading its backlog slowly must not be reaped as
                // idle). A zero-byte WouldBlock is deliberately not a
                // refresh, so a fully stalled peer still ages out.
                if conn.encoder.pending() < before {
                    conn.last_activity = Instant::now();
                }
            }
            Err(_) => {
                conn.dead = true;
                return;
            }
        }
    }
    if !conn.closing && conn.encoder.pending() < READ_PAUSE_BYTES && conn.decoder.buffered() > 0
    {
        process_frames(conn, shared);
    }
    if conn.closing && conn.encoder.is_empty() {
        conn.dead = true;
    }
}

/// Ship the `Snapshot` RPC's registry walk and file write to the worker
/// pool; the reply comes back as a [`CompletionKind::Reply`]. Returns
/// `false` when the pool refused (shutdown race) — the caller serves
/// the request inline instead.
fn submit_snapshot_job(conn: &mut Conn, shared: &Arc<Shared>, trace_id: u64) -> bool {
    let Some(workers) = &shared.workers else { return false };
    let Some(path) = shared.cfg.snapshot_path.clone() else { return false };
    let job_shared = shared.clone();
    let (loop_idx, slot, gen) = (conn.loop_idx, conn.slot, conn.gen);
    let submitted = workers.submit(Box::new(move || {
        let resp = {
            // The dispatch span moves with the work: a traced snapshot
            // shows its real (off-loop) duration, not the submit cost.
            let _span = Span::enter_timed(
                Stage::Dispatch,
                trace_id,
                job_shared.timers.timer(Stage::Dispatch),
            );
            match snapshot::write_snapshot(&job_shared.registry, &path) {
                Ok(s) => Response::SnapshotDone { keys: s.keys, bytes: s.bytes },
                Err(e) => Response::Error { code: ErrorCode::Internal, message: e.to_string() },
            }
        };
        job_shared.deliver(
            loop_idx,
            Completion { conn_idx: slot, gen, kind: CompletionKind::Reply(resp) },
        );
    }));
    if submitted {
        conn.awaiting = true;
    }
    submitted
}

/// Land one worker-pool result on its connection: clear the halt, queue
/// the reply (or the full-sync frame), and resume the frames that
/// buffered up behind the offloaded one. Results addressed to a
/// connection that died — slot empty, or reused under a newer
/// generation — are dropped.
fn apply_completion(conns: &mut [Option<Conn>], shared: &Arc<Shared>, done: Completion) {
    let Some(conn) = conns.get_mut(done.conn_idx).and_then(|s| s.as_mut()) else { return };
    if conn.gen != done.gen || conn.dead {
        return;
    }
    conn.awaiting = false;
    conn.last_activity = Instant::now();
    match done.kind {
        CompletionKind::Reply(resp) => {
            queue_reply(conn, shared, resp);
            process_frames(conn, shared);
        }
        CompletionKind::FullSync { epoch, cursor, body } => {
            // Same frame-cap check as the inline path in
            // `push_full_sync` — the image was built off-loop, the
            // verdict is delivered here.
            if body.len() as u64 + 20 > MAX_PAYLOAD as u64 {
                queue_reply(
                    conn,
                    shared,
                    Response::Error {
                        code: ErrorCode::Internal,
                        message: format!(
                            "registry image of {} bytes exceeds the in-band full-sync frame \
                             cap; bootstrap this follower from a snapshot file",
                            body.len()
                        ),
                    },
                );
                conn.closing = true;
                return;
            }
            conn.encoder.push(Response::FullSync { epoch, cursor, body }.encode());
            shared.stats.full_syncs_sent.fetch_add(1, Ordering::Relaxed);
            if let ConnMode::Subscriber { sent, acked, .. } = &mut conn.mode {
                *sent = cursor;
                *acked = cursor;
            }
            // Batches sealed while the image was being built ship now.
            if let Some(log) = shared.log.clone() {
                pump_subscriber(conn, shared, &log);
            }
            process_frames(conn, shared);
        }
    }
}

// ---------------------------------------------------------------------------
// Maintenance threads
// ---------------------------------------------------------------------------

/// Worker-pool thread: pull blocking jobs off the shared queue and run
/// them. The receiver lock is held only for the bounded wait, never
/// while a job runs, so siblings keep draining the queue; the bounded
/// wait doubles as the stop-flag poll for shutdown.
fn worker_loop(shared: Arc<Shared>, rx: Arc<Mutex<mpsc::Receiver<Job>>>) {
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        let job = match rx.lock() {
            Ok(rx) => rx.recv_timeout(Duration::from_millis(25)),
            Err(_) => break,
        };
        match job {
            Ok(job) => job(),
            Err(mpsc::RecvTimeoutError::Timeout) => continue,
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }
}

/// Replication capture thread: drain the registry's dirty keys (and the
/// global union's dirty registers) into a sealed [`ReplicationLog`]
/// batch on the configured cadence, then wake every event loop so
/// subscriber connections re-arm write interest and ship it. One
/// capturer per primary; subscriber connections only *read* the log.
fn capture_loop(shared: Arc<Shared>, log: Arc<ReplicationLog>, cfg: ReplicationConfig) {
    let mut last = Instant::now();
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
        if last.elapsed() < cfg.capture_interval {
            continue;
        }
        last = Instant::now();
        if log.capture(&shared.registry, cfg.retain_bytes).is_some() {
            shared.wake_all();
        }
    }
}

/// Background maintenance sweeper: timer-driven TTL / wall-TTL / budget
/// eviction (previously only reachable through ingest piggybacking and
/// the `Evict` RPC). Polls the stop flag between short sleeps so
/// shutdown joins it within a few milliseconds regardless of the
/// configured interval.
fn sweeper_loop(shared: Arc<Shared>, cfg: SweeperConfig) {
    let mut last = Instant::now();
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
        if last.elapsed() < cfg.interval {
            continue;
        }
        last = Instant::now();
        let mut swept = 0usize;
        if let Some(max_ticks) = cfg.idle_max_ticks {
            swept += shared.registry.evict_idle(max_ticks);
        }
        if let Some(max_age) = cfg.idle_max_age {
            swept += shared.registry.evict_idle_wall(max_age);
        }
        if cfg.enforce_budget {
            swept += shared.registry.enforce_budget();
        }
        shared.stats.sweeps.fetch_add(1, Ordering::Relaxed);
        shared.stats.keys_swept.fetch_add(swept as u64, Ordering::Relaxed);
        if swept > 0 {
            crate::log_debug!("server", "sweeper evicted {swept} keys");
        }
    }
}

// ---------------------------------------------------------------------------
// Wire helpers and dispatch (shared with the follower)
// ---------------------------------------------------------------------------

/// Drain `buf` into the stream, polling the stop flag across write
/// timeouts — the *blocking* write helper the follower's replication
/// thread still uses for its subscribe and ack frames (the follower is
/// a client-side thread, not part of the event loop).
pub(crate) fn write_full(stream: &mut TcpStream, buf: &[u8], stop: &AtomicBool) -> io::Result<bool> {
    let mut written = 0;
    while written < buf.len() {
        if stop.load(Ordering::SeqCst) {
            return Ok(false);
        }
        match stream.write(&buf[written..]) {
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::WriteZero,
                    "peer stopped accepting bytes",
                ))
            }
            Ok(n) => written += n,
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::WouldBlock
                        | io::ErrorKind::TimedOut
                        | io::ErrorKind::Interrupted
                ) =>
            {
                continue
            }
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// Encode one sealed batch for a subscriber's negotiated delta wire.
/// Current (v3) subscribers get the typed entries verbatim; legacy
/// (v2) subscribers get the shape they understand — full sketches only:
/// register diffs inflate into a sketch holding just those registers
/// (zeros never lower anything under max-merge), while tombstones and
/// global-union diffs are dropped, leaving legacy followers grow-only
/// with a live-keys-derived global exactly as they were before those
/// entry kinds existed. An emptied batch still ships, so the
/// subscriber's cursor advances past it.
///
/// Returns `None` when the legacy rendering cannot fit one frame: the
/// batch was size-budgeted in *diff* bytes, and inflating every diff to
/// a full 2^p-byte sketch can multiply it past [`MAX_PAYLOAD`] (~3600×
/// at the paper config in the worst case). The running size is checked
/// before each sketch is materialized — an overflowing batch allocates
/// at most the frame cap before bailing — and the caller answers a
/// terminal typed error instead of streaming a frame the follower's
/// header parser would reject on every reconnect forever.
fn encode_batch_for_wire(batch: &SealedBatch, wire: u8) -> Option<Vec<u8>> {
    if wire >= DELTA_WIRE_V4 {
        // v4 subscribers additionally get the last-writer trace IDs
        // sealed with the batch (a kind-5 metadata entry a v3 decoder
        // would reject, hence the gate).
        return Some(encode_delta_batch_v4(
            batch.seq,
            &batch.entries,
            batch.sealed_unix_ns,
            &batch.writer_traces,
        ));
    }
    if wire >= DELTA_WIRE_V3 {
        return Some(encode_delta_batch_v3(batch.seq, &batch.entries, batch.sealed_unix_ns));
    }
    let mut legacy: Vec<(u64, Vec<u8>)> = Vec::with_capacity(batch.entries.len());
    let mut total = 12u64;
    for (key, delta) in &batch.entries {
        match delta {
            SketchDelta::Full(bytes) => {
                total += 12 + bytes.len() as u64;
                if total > MAX_PAYLOAD as u64 {
                    return None;
                }
                legacy.push((*key, bytes.clone()));
            }
            SketchDelta::RegisterDiff(bytes) => {
                // Sealed diffs came from our own drain; a decode failure
                // here would be a local invariant break, so skipping the
                // entry (follower falls back to grow-only staleness for
                // that key until its next full resend) beats wedging the
                // stream.
                if let Ok((cfg, entries)) = decode_register_diff(bytes) {
                    total += 12 + HllSketch::wire_len(&cfg) as u64;
                    if total > MAX_PAYLOAD as u64 {
                        return None;
                    }
                    let mut sketch = HllSketch::new(cfg);
                    sketch.apply_register_diff(&entries);
                    legacy.push((*key, sketch.to_bytes()));
                }
            }
            SketchDelta::Tombstone | SketchDelta::GlobalDiff(_) => {}
        }
    }
    Some(encode_delta_batch(batch.seq, &legacy))
}

fn dispatch(req: Request, shared: &Shared, trace_id: u64) -> Response {
    let _dispatch_span =
        Span::enter_timed(Stage::Dispatch, trace_id, shared.timers.timer(Stage::Dispatch));
    let registry = &shared.registry;
    // A read-only replica rejects every mutating RPC with a typed frame
    // before touching the registry; queries pass through untouched
    // (including `TraceDump` — it is how a replica's flight recorder is
    // read).
    if shared.cfg.read_only
        && matches!(
            req,
            Request::InsertBatch { .. }
                | Request::MergeSketch { .. }
                | Request::Evict(_)
                | Request::Snapshot
        )
    {
        return Response::Error {
            code: ErrorCode::ReadOnly,
            message: "replica is read-only; send writes to the primary".into(),
        };
    }
    match req {
        Request::Ping => Response::Pong,
        Request::InsertBatch { key, words } => {
            let n = words.len() as u64;
            // A traced write deposits its ID with the replication log
            // *before* mutating the registry: the capture thread drains
            // deposits only when it seals dirty entries, so the ID
            // rides the batch covering this ingest (or, across a seal
            // race, the immediately preceding one — both are "last
            // writers" of the sealed window).
            if trace_id != 0 {
                if let Some(log) = &shared.log {
                    log.note_writer_trace(trace_id);
                }
            }
            {
                // One span covers the whole routed run: `ingest` is the
                // batch-native entry point (hash every word in one tight
                // loop into pooled scratch, raise the global union in
                // one pass, fold the run into the key's sketch under a
                // single shard-lock acquisition).
                let _ingest_span = Span::enter_timed(
                    Stage::ShardIngest,
                    trace_id,
                    shared.timers.timer(Stage::ShardIngest),
                )
                .with_payload(n);
                registry.ingest(key, &words);
            }
            shared.stats.words_ingested.fetch_add(n, Ordering::Relaxed);
            // A registry configured with a memory budget holds it without
            // every client having to know the cap: enforcement is
            // periodic because the accounting walk is O(keys).
            if registry.config().max_memory_bytes.is_some()
                && shared.stats.frames.load(Ordering::Relaxed) % BUDGET_ENFORCE_EVERY == 0
            {
                let evicted = registry.enforce_budget();
                shared.stats.keys_evicted.fetch_add(evicted as u64, Ordering::Relaxed);
            }
            Response::Ingested { words: n }
        }
        Request::Estimate { key } => Response::Estimate(registry.estimate(&key)),
        Request::GlobalEstimate => Response::GlobalEstimate(registry.global_estimate()),
        Request::MergeSketch { key, bytes } => match HllSketch::from_bytes(&bytes) {
            Ok(sketch) => {
                // Stats-drift fix: merged sketches used to bypass the
                // ingest counter entirely, so a merge-heavy workload
                // reported near-zero ingest. The wire carries no word
                // count, so credit the sketch's own cardinality
                // estimate — a documented lower bound (overlap with
                // already-ingested words is invisible).
                let approx_words = sketch.estimate().round().max(0.0) as u64;
                match registry.merge_sketch(key, sketch) {
                    Ok(()) => {
                        shared.stats.sketches_merged.fetch_add(1, Ordering::Relaxed);
                        shared.stats.words_ingested.fetch_add(approx_words, Ordering::Relaxed);
                        Response::Merged
                    }
                    Err(e @ SketchError::ConfigMismatch(..)) => Response::Error {
                        code: ErrorCode::ConfigMismatch,
                        message: e.to_string(),
                    },
                    Err(e) => {
                        Response::Error { code: ErrorCode::Malformed, message: e.to_string() }
                    }
                }
            }
            Err(e) => Response::Error { code: ErrorCode::Malformed, message: e.to_string() },
        },
        Request::Stats => Response::Stats(StatsSummary::from(&registry.stats())),
        // Served on read-only replicas too (it is how their lag is
        // observed); renders every registered instrument, including the
        // scrape-time bridged gauges.
        Request::MetricsDump => Response::MetricsText(shared.metrics.render()),
        // The flight recorder's recent events, merged across every
        // thread's ring and capped to the newest. Served on read-only
        // replicas too (it is how a follower's apply spans are read);
        // also the capability probe a tracing client sends — a
        // pre-tracing server answers a typed BadOpcode error instead.
        Request::TraceDump => {
            Response::TraceEvents { events: recorder::snapshot(TRACE_DUMP_MAX_EVENTS) }
        }
        Request::Evict(policy) => {
            let keys = match policy {
                EvictPolicy::Key(key) => registry.evict(&key).is_some() as u64,
                EvictPolicy::Idle { max_age } => registry.evict_idle(max_age) as u64,
                EvictPolicy::Budget { max_memory_bytes } => {
                    // Saturate rather than truncate: `as usize` would wrap
                    // a >= 4 GiB budget to ~0 on a 32-bit server and
                    // mass-evict the registry.
                    let budget = usize::try_from(max_memory_bytes).unwrap_or(usize::MAX);
                    registry.evict_to_budget(budget) as u64
                }
                EvictPolicy::IdleWall { max_age_secs } => {
                    registry.evict_idle_wall(Duration::from_secs(max_age_secs)) as u64
                }
            };
            shared.stats.keys_evicted.fetch_add(keys, Ordering::Relaxed);
            Response::Evicted { keys }
        }
        Request::Snapshot => match &shared.cfg.snapshot_path {
            Some(path) => match snapshot::write_snapshot(registry, path) {
                Ok(s) => Response::SnapshotDone { keys: s.keys, bytes: s.bytes },
                Err(e) => {
                    Response::Error { code: ErrorCode::Internal, message: e.to_string() }
                }
            },
            None => Response::Error {
                code: ErrorCode::Unsupported,
                message: "server started without a snapshot path".into(),
            },
        },
        // Handled at the connection layer (handle_rpc_frame) before
        // dispatch; unreachable in practice, answered typed regardless.
        Request::Subscribe { .. } | Request::ReplicaAck { .. } => Response::Error {
            code: ErrorCode::Malformed,
            message: "replication frames are handled at the connection layer".into(),
        },
    }
}
