//! A minimal `poll(2)` reactor — the readiness substrate of the
//! event-driven server ([`super::server`]).
//!
//! The offline crate set has no `mio`/`libc`, so this is a hand-rolled
//! wrapper over the one portable-enough readiness syscall `std` links
//! anyway: `poll(2)`, declared directly via `extern "C"` with our own
//! `pollfd` layout. The interest set is rebuilt from scratch every loop
//! iteration (the classic poll shape): registration is just pushing
//! into a vector, there is no persistent kernel-side state to keep
//! consistent, and interest *flipping* — the server's write
//! backpressure mechanism — is simply "register with different flags
//! next tick". O(connections) per tick, which is exactly the regime the
//! paper's single shared datapath lives in and comfortably handles the
//! hundreds-to-thousands of connections this server targets. (An
//! epoll/kqueue upgrade would slot in behind the same three-method
//! surface: `clear` / `register` / `poll`.)
//!
//! Cross-thread wakeups use a [`Waker`]: a nonblocking
//! [`UnixStream::pair`] self-pipe whose read end rides in the poll set.
//! Anything may call [`Waker::wake`] from any thread — the replication
//! capture thread does, after sealing a batch, so subscriber
//! connections re-arm write interest within one syscall instead of one
//! poll timeout; shutdown does, so loops exit immediately.
//!
//! Unix-only by construction (as is `poll(2)`); the serving stack
//! targets the Linux containers CI and production run on.

use std::io::{self, Read, Write};
use std::os::raw::c_int;
use std::os::unix::io::{AsRawFd, RawFd};
use std::os::unix::net::UnixStream;
use std::time::Duration;

/// `struct pollfd` — identical layout on every unix libc.
#[repr(C)]
#[derive(Debug, Clone, Copy)]
struct PollFd {
    fd: RawFd,
    events: i16,
    revents: i16,
}

const POLLIN: i16 = 0x001;
const POLLOUT: i16 = 0x004;
const POLLERR: i16 = 0x008;
const POLLHUP: i16 = 0x010;
const POLLNVAL: i16 = 0x020;

#[cfg(target_os = "linux")]
type Nfds = std::os::raw::c_ulong;
#[cfg(not(target_os = "linux"))]
type Nfds = std::os::raw::c_uint;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: Nfds, timeout: c_int) -> c_int;
}

/// One ready descriptor, translated out of `revents`.
#[derive(Debug, Clone, Copy)]
pub struct Readiness {
    /// The caller-chosen token passed to [`Poller::register`].
    pub token: usize,
    /// Readable — includes `POLLHUP`/`POLLERR`, so the owner's next
    /// `read` surfaces the EOF or error instead of the event being
    /// silently dropped.
    pub readable: bool,
    /// Writable — includes `POLLERR` for the same reason.
    pub writable: bool,
    /// The fd is invalid (`POLLNVAL`): close the connection outright.
    pub invalid: bool,
}

/// A rebuilt-per-tick `poll(2)` interest set.
#[derive(Debug, Default)]
pub struct Poller {
    fds: Vec<PollFd>,
    tokens: Vec<usize>,
}

impl Poller {
    pub fn new() -> Self {
        Self::default()
    }

    /// Drop all registrations (start of a new tick).
    pub fn clear(&mut self) {
        self.fds.clear();
        self.tokens.clear();
    }

    /// Add `fd` to this tick's interest set under `token`. Registering
    /// with neither interest still reports errors/hangups (poll always
    /// delivers those).
    pub fn register(&mut self, fd: RawFd, token: usize, readable: bool, writable: bool) {
        let mut events = 0i16;
        if readable {
            events |= POLLIN;
        }
        if writable {
            events |= POLLOUT;
        }
        self.fds.push(PollFd { fd, events, revents: 0 });
        self.tokens.push(token);
    }

    /// Block until at least one registered fd is ready or `timeout`
    /// elapses (`None` = wait forever). Returns the ready count (0 =
    /// timeout). `EINTR` retries with the full timeout — callers poll
    /// on short ticks, so the drift is bounded and harmless.
    pub fn poll(&mut self, timeout: Option<Duration>) -> io::Result<usize> {
        let timeout_ms: c_int = match timeout {
            None => -1,
            Some(d) => d.as_millis().min(c_int::MAX as u128) as c_int,
        };
        loop {
            let rc = unsafe { poll(self.fds.as_mut_ptr(), self.fds.len() as Nfds, timeout_ms) };
            if rc >= 0 {
                return Ok(rc as usize);
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }

    /// Iterate this tick's ready descriptors (entries whose `revents`
    /// came back nonzero).
    pub fn ready(&self) -> impl Iterator<Item = Readiness> + '_ {
        self.fds.iter().zip(&self.tokens).filter(|(fd, _)| fd.revents != 0).map(|(fd, &token)| {
            Readiness {
                token,
                readable: fd.revents & (POLLIN | POLLHUP | POLLERR) != 0,
                writable: fd.revents & (POLLOUT | POLLERR) != 0,
                invalid: fd.revents & POLLNVAL != 0,
            }
        })
    }
}

/// The write end of a loop's self-pipe: wake the loop out of `poll`
/// from any thread. Wakes coalesce — if the pipe already holds an
/// unread byte the write would block and is dropped, which is exactly
/// the "a wake is already pending" case.
#[derive(Debug)]
pub struct Waker {
    tx: UnixStream,
}

impl Waker {
    pub fn wake(&self) {
        let _ = (&self.tx).write(&[1u8]);
    }
}

/// The read end of a loop's self-pipe; registered readable in the
/// loop's poll set every tick.
#[derive(Debug)]
pub struct WakeRx {
    rx: UnixStream,
}

impl WakeRx {
    pub fn as_raw_fd(&self) -> RawFd {
        self.rx.as_raw_fd()
    }

    /// Swallow all pending wake bytes (level-triggered poll would
    /// otherwise re-report forever).
    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        while matches!((&self.rx).read(&mut buf), Ok(n) if n > 0) {}
    }
}

/// A connected nonblocking self-pipe pair.
pub fn waker_pair() -> io::Result<(Waker, WakeRx)> {
    let (tx, rx) = UnixStream::pair()?;
    tx.set_nonblocking(true)?;
    rx.set_nonblocking(true)?;
    Ok((Waker { tx }, WakeRx { rx }))
}

/// Per-event-loop tick profiler: where does a loop's wall time go?
///
/// Each tick splits into *wait* (blocked in `poll(2)`) and *work*
/// (dispatching ready connections, pumping subscribers, reaping). Both
/// land in lock-free [`LatencyHistogram`]s, ready-event counts per tick
/// land in a third, and a saturation gauge reports
/// `work / (work + wait)` in permille over an exponentially decayed
/// window — the "is this loop the bottleneck?" number the C100K roadmap
/// item gates on. Recording is a handful of relaxed atomics per tick;
/// only the loop thread calls [`TickProfile::tick`], scrapers read the
/// shared histograms.
#[derive(Debug)]
pub struct TickProfile {
    poll_wait_ns: std::sync::Arc<crate::obs::LatencyHistogram>,
    work_ns: std::sync::Arc<crate::obs::LatencyHistogram>,
    ready_events: std::sync::Arc<crate::obs::LatencyHistogram>,
    saturation_permille: crate::obs::Gauge,
    /// Decayed accumulators (loop-thread-local; plain fields would do,
    /// but keeping the struct `Sync` costs nothing).
    busy_ns_acc: std::sync::atomic::AtomicU64,
    wait_ns_acc: std::sync::atomic::AtomicU64,
}

/// Decay window for the saturation gauge: once busy+wait exceeds ~5 s,
/// both halve, so the gauge tracks recent load instead of the lifetime
/// average.
const SATURATION_WINDOW_NS: u64 = 5_000_000_000;

impl TickProfile {
    /// Register this loop's tick series into `metrics` under a
    /// `loop="N"` label.
    pub fn register(metrics: &crate::obs::MetricsRegistry, loop_idx: usize) -> Self {
        let label = loop_label(loop_idx);
        let l = || Some(("loop", label.to_string()));
        Self {
            poll_wait_ns: metrics.histogram("loop_poll_wait_ns", l()),
            work_ns: metrics.histogram("loop_work_ns", l()),
            ready_events: metrics.histogram("loop_ready_events", l()),
            saturation_permille: metrics.gauge("loop_saturation_permille", l()),
            busy_ns_acc: std::sync::atomic::AtomicU64::new(0),
            wait_ns_acc: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Record one completed tick: `work` spent dispatching before the
    /// poll, `wait` blocked inside it, `ready` descriptors it returned.
    pub fn tick(&self, work: Duration, wait: Duration, ready: usize) {
        use std::sync::atomic::Ordering;
        let work_ns = work.as_nanos().min(u64::MAX as u128) as u64;
        let wait_ns = wait.as_nanos().min(u64::MAX as u128) as u64;
        self.work_ns.record(work_ns);
        self.poll_wait_ns.record(wait_ns);
        self.ready_events.record(ready as u64);
        // Exponentially decayed busy fraction: halve both accumulators
        // whenever the window fills, then publish permille.
        let mut busy = self.busy_ns_acc.load(Ordering::Relaxed) + work_ns;
        let mut wait_acc = self.wait_ns_acc.load(Ordering::Relaxed) + wait_ns;
        if busy + wait_acc > SATURATION_WINDOW_NS {
            busy /= 2;
            wait_acc /= 2;
        }
        self.busy_ns_acc.store(busy, Ordering::Relaxed);
        self.wait_ns_acc.store(wait_acc, Ordering::Relaxed);
        let total = busy + wait_acc;
        if total > 0 {
            self.saturation_permille.set(busy * 1_000 / total);
        }
    }
}

/// Static label for a loop index ("0".."15", then "n" — metric labels
/// are `&'static str`, and 16 loops is already past the configured
/// maximum anyone runs).
fn loop_label(i: usize) -> &'static str {
    const LABELS: [&str; 16] = [
        "0", "1", "2", "3", "4", "5", "6", "7", "8", "9", "10", "11", "12", "13", "14", "15",
    ];
    LABELS.get(i).copied().unwrap_or("n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};
    use std::time::Duration;

    #[test]
    fn waker_crosses_poll_and_coalesces() {
        let (waker, rx) = waker_pair().unwrap();
        let mut poller = Poller::new();
        // No wake pending: poll times out.
        poller.clear();
        poller.register(rx.as_raw_fd(), 1, true, false);
        assert_eq!(poller.poll(Some(Duration::from_millis(10))).unwrap(), 0);
        // Wakes (from another thread) make the pipe readable; repeated
        // wakes coalesce and drain clears them.
        let t = std::thread::spawn(move || {
            for _ in 0..100 {
                waker.wake();
            }
            waker
        });
        let _waker = t.join().unwrap();
        poller.clear();
        poller.register(rx.as_raw_fd(), 1, true, false);
        assert_eq!(poller.poll(Some(Duration::from_secs(5))).unwrap(), 1);
        let ready: Vec<Readiness> = poller.ready().collect();
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].token, 1);
        assert!(ready[0].readable);
        rx.drain();
        poller.clear();
        poller.register(rx.as_raw_fd(), 1, true, false);
        assert_eq!(poller.poll(Some(Duration::from_millis(10))).unwrap(), 0, "drained");
    }

    #[test]
    fn poller_reports_tcp_readability_and_writability() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut poller = Poller::new();

        // Nothing pending: the listener is not readable.
        poller.clear();
        poller.register(listener.as_raw_fd(), 7, true, false);
        assert_eq!(poller.poll(Some(Duration::from_millis(10))).unwrap(), 0);

        // A pending connection makes it readable.
        let client = TcpStream::connect(addr).unwrap();
        poller.clear();
        poller.register(listener.as_raw_fd(), 7, true, false);
        assert_eq!(poller.poll(Some(Duration::from_secs(5))).unwrap(), 1);
        assert!(poller.ready().any(|r| r.token == 7 && r.readable));
        let (server_side, _) = listener.accept().unwrap();

        // A fresh connected socket: writable, not readable.
        poller.clear();
        poller.register(client.as_raw_fd(), 8, true, true);
        assert_eq!(poller.poll(Some(Duration::from_secs(5))).unwrap(), 1);
        let r: Vec<Readiness> = poller.ready().collect();
        assert!(r[0].writable && !r[0].readable);

        // Peer data arrives: readable too.
        (&server_side).write_all(&[9u8; 4]).unwrap();
        poller.clear();
        poller.register(client.as_raw_fd(), 8, true, false);
        assert_eq!(poller.poll(Some(Duration::from_secs(5))).unwrap(), 1);
        assert!(poller.ready().any(|r| r.token == 8 && r.readable));
    }
}
