//! Readiness substrate of the event-driven server ([`super::server`]):
//! a three-method `Poller` surface (`clear` / `register` / `poll`) with
//! two interchangeable kernel backends.
//!
//! The offline crate set has no `mio`/`libc`, so both backends are
//! hand-rolled `extern "C"` wrappers with our own struct layouts:
//!
//! * **`poll(2)`** — the portable baseline. The interest set is rebuilt
//!   from scratch every loop iteration (the classic poll shape):
//!   registration is just pushing into a vector and there is no
//!   persistent kernel-side state to keep consistent. The kernel scans
//!   the whole set per call, so per-tick cost is O(resident
//!   connections) — fine to ~1k conns, the wall the C100K roadmap item
//!   hits.
//! * **`epoll(7)`** (Linux) — level-triggered, persistent kernel
//!   interest. `clear`/`register` only mutate a userspace *desired*
//!   set; `poll` diffs it against a mirror of what the kernel currently
//!   holds and issues `EPOLL_CTL_ADD/MOD/DEL` **only on state change**.
//!   A steady-state tick where no connection flipped interest performs
//!   exactly one syscall (`epoll_wait`), and `epoll_wait` returns in
//!   O(ready), not O(registered) — per-tick cost flat in connection
//!   count.
//! * **`kqueue(2)`** (BSD/macOS) — selection stub only: the backend
//!   enum carries the variant and `resolve()` falls back to `poll`
//!   until a `kevent` wrapper lands (struct kevent layouts diverge
//!   across the BSDs; the poll backend is correct everywhere).
//!
//! Backend choice is [`PollerBackend`]: servers default to
//! `Auto` (= best available for the platform, overridable with
//! `HLL_POLLER=poll|epoll|kqueue`); an explicitly requested backend
//! that is unavailable falls back to the best available one.
//!
//! Interest *flipping* — the server's write-backpressure mechanism —
//! stays "register with different flags next tick" under every backend;
//! epoll turns the flip into a single `EPOLL_CTL_MOD` for just the
//! connection that changed.
//!
//! Cross-thread wakeups use a [`Waker`]: a nonblocking
//! [`UnixStream::pair`] self-pipe whose read end rides in the poll set.
//! Anything may call [`Waker::wake`] from any thread — the replication
//! capture thread does, after sealing a batch, so subscriber
//! connections re-arm write interest within one syscall instead of one
//! poll timeout; worker-pool threads do, to deliver completed blocking
//! work back to the owning loop; shutdown does, so loops exit
//! immediately.
//!
//! Unix-only by construction (as is `poll(2)`); the serving stack
//! targets the Linux containers CI and production run on.

use std::io::{self, Read, Write};
use std::os::raw::c_int;
use std::os::unix::io::{AsRawFd, RawFd};
use std::os::unix::net::UnixStream;
use std::time::Duration;

// ---------------------------------------------------------------------------
// Backend selection
// ---------------------------------------------------------------------------

/// Which kernel readiness API a [`Poller`] uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PollerBackend {
    /// Best available for the platform, overridable via `HLL_POLLER`.
    #[default]
    Auto,
    /// Portable `poll(2)`: interest rebuilt per tick, O(conns)/tick.
    Poll,
    /// Linux `epoll(7)`: persistent interest, ctl only on state change.
    Epoll,
    /// BSD/macOS `kqueue(2)` — selection stub; resolves to `poll` today.
    Kqueue,
}

impl PollerBackend {
    /// Backends that actually work on this platform, best first.
    pub fn available() -> &'static [PollerBackend] {
        #[cfg(target_os = "linux")]
        {
            &[PollerBackend::Epoll, PollerBackend::Poll]
        }
        #[cfg(not(target_os = "linux"))]
        {
            &[PollerBackend::Poll]
        }
    }

    /// Best backend this platform supports.
    pub fn best() -> PollerBackend {
        Self::available()[0]
    }

    fn is_available(self) -> bool {
        Self::available().contains(&self)
    }

    /// Parse a backend name (the `HLL_POLLER` value format).
    pub fn parse(s: &str) -> Option<PollerBackend> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Some(PollerBackend::Auto),
            "poll" => Some(PollerBackend::Poll),
            "epoll" => Some(PollerBackend::Epoll),
            "kqueue" => Some(PollerBackend::Kqueue),
            _ => None,
        }
    }

    /// The `HLL_POLLER` environment override, if set to a known name.
    pub fn from_env() -> Option<PollerBackend> {
        std::env::var("HLL_POLLER").ok().and_then(|v| Self::parse(&v))
    }

    /// Resolve to a concrete, available backend: `Auto` honors the
    /// `HLL_POLLER` override and otherwise picks [`Self::best`]; an
    /// explicit but unavailable choice (e.g. `epoll` on macOS, or the
    /// `kqueue` stub anywhere) falls back to [`Self::best`].
    pub fn resolve(self) -> PollerBackend {
        let requested = match self {
            PollerBackend::Auto => Self::from_env().unwrap_or_else(Self::best),
            explicit => explicit,
        };
        let requested = match requested {
            PollerBackend::Auto => Self::best(),
            other => other,
        };
        if requested.is_available() {
            requested
        } else {
            Self::best()
        }
    }

    /// Stable label for metrics and logs.
    pub fn label(self) -> &'static str {
        match self {
            PollerBackend::Auto => "auto",
            PollerBackend::Poll => "poll",
            PollerBackend::Epoll => "epoll",
            PollerBackend::Kqueue => "kqueue",
        }
    }
}

// ---------------------------------------------------------------------------
// Shared readiness type
// ---------------------------------------------------------------------------

/// One ready descriptor, translated out of the backend's event record.
#[derive(Debug, Clone, Copy)]
pub struct Readiness {
    /// The caller-chosen token passed to [`Poller::register`].
    pub token: usize,
    /// Readable — includes hangup/error conditions, so the owner's next
    /// `read` surfaces the EOF or error instead of the event being
    /// silently dropped.
    pub readable: bool,
    /// Writable — includes error conditions for the same reason.
    pub writable: bool,
    /// The fd is invalid (`POLLNVAL`, or an `epoll_ctl` the kernel
    /// refused): close the connection outright.
    pub invalid: bool,
}

// ---------------------------------------------------------------------------
// poll(2) backend
// ---------------------------------------------------------------------------

/// `struct pollfd` — identical layout on every unix libc.
#[repr(C)]
#[derive(Debug, Clone, Copy)]
struct PollFd {
    fd: RawFd,
    events: i16,
    revents: i16,
}

const POLLIN: i16 = 0x001;
const POLLOUT: i16 = 0x004;
const POLLERR: i16 = 0x008;
const POLLHUP: i16 = 0x010;
const POLLNVAL: i16 = 0x020;

#[cfg(target_os = "linux")]
type Nfds = std::os::raw::c_ulong;
#[cfg(not(target_os = "linux"))]
type Nfds = std::os::raw::c_uint;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: Nfds, timeout: c_int) -> c_int;
}

/// A rebuilt-per-tick `poll(2)` interest set.
#[derive(Debug, Default)]
struct PollSet {
    fds: Vec<PollFd>,
    tokens: Vec<usize>,
}

impl PollSet {
    fn clear(&mut self) {
        self.fds.clear();
        self.tokens.clear();
    }

    fn register(&mut self, fd: RawFd, token: usize, readable: bool, writable: bool) {
        let mut events = 0i16;
        if readable {
            events |= POLLIN;
        }
        if writable {
            events |= POLLOUT;
        }
        self.fds.push(PollFd { fd, events, revents: 0 });
        self.tokens.push(token);
    }

    fn poll(&mut self, timeout: Option<Duration>, out: &mut Vec<Readiness>) -> io::Result<usize> {
        let timeout_ms = timeout_millis(timeout);
        loop {
            let rc = unsafe { poll(self.fds.as_mut_ptr(), self.fds.len() as Nfds, timeout_ms) };
            if rc >= 0 {
                break;
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
        for (fd, &token) in self.fds.iter().zip(&self.tokens) {
            if fd.revents == 0 {
                continue;
            }
            out.push(Readiness {
                token,
                readable: fd.revents & (POLLIN | POLLHUP | POLLERR) != 0,
                writable: fd.revents & (POLLOUT | POLLERR) != 0,
                invalid: fd.revents & POLLNVAL != 0,
            });
        }
        Ok(out.len())
    }
}

/// Millisecond timeout in poll/epoll convention (`-1` = forever).
fn timeout_millis(timeout: Option<Duration>) -> c_int {
    match timeout {
        None => -1,
        Some(d) => d.as_millis().min(c_int::MAX as u128) as c_int,
    }
}

// ---------------------------------------------------------------------------
// epoll(7) backend (Linux)
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod epoll {
    use super::{timeout_millis, Readiness};
    use std::collections::HashMap;
    use std::io;
    use std::os::raw::c_int;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    const EPOLL_CLOEXEC: c_int = 0o2000000;
    const EPOLL_CTL_ADD: c_int = 1;
    const EPOLL_CTL_DEL: c_int = 2;
    const EPOLL_CTL_MOD: c_int = 3;

    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;

    const ENOENT: i32 = 2;
    const EEXIST: i32 = 17;

    /// `struct epoll_event`. Packed on x86/x86_64 (the kernel ABI), the
    /// natural C layout elsewhere — the same dance libc does.
    #[repr(C)]
    #[cfg_attr(any(target_arch = "x86", target_arch = "x86_64"), repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        /// Carries the caller token. Never a pointer, so fd-reuse can't
        /// dangle anything.
        data: u64,
    }

    impl std::fmt::Debug for EpollEvent {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            // Copy out of the (possibly packed) struct before formatting.
            let (events, data) = (self.events, self.data);
            f.debug_struct("EpollEvent").field("events", &events).field("data", &data).finish()
        }
    }

    extern "C" {
        fn epoll_create1(flags: c_int) -> c_int;
        fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        fn epoll_wait(epfd: c_int, events: *mut EpollEvent, maxevents: c_int, timeout: c_int) -> c_int;
        fn close(fd: c_int) -> c_int;
    }

    /// Per-fd interest record: what the caller wants this tick vs what
    /// the kernel currently holds.
    #[derive(Debug)]
    struct Entry {
        token: usize,
        /// Desired event mask as of generation `gen`.
        want: u32,
        /// Generation of the last `register` for this fd; entries whose
        /// generation lags the poller's were dropped by the caller and
        /// get an `EPOLL_CTL_DEL`.
        gen: u64,
        /// `(token, mask)` the kernel currently has registered, if any.
        kernel: Option<(usize, u32)>,
    }

    /// Persistent-interest epoll set. `clear`/`register` touch only the
    /// userspace desired set; [`EpollSet::poll`] reconciles it against
    /// the kernel mirror with the minimal `epoll_ctl` sequence, then
    /// waits.
    #[derive(Debug)]
    pub(super) struct EpollSet {
        epfd: RawFd,
        entries: HashMap<RawFd, Entry>,
        /// Current registration generation; bumped by `clear`.
        gen: u64,
        events: Vec<EpollEvent>,
    }

    fn ctl_op(epfd: RawFd, op: c_int, fd: RawFd, mask: u32, token: usize) -> io::Result<()> {
        let mut ev = EpollEvent { events: mask, data: token as u64 };
        let rc = unsafe { epoll_ctl(epfd, op, fd, &mut ev) };
        if rc == 0 {
            Ok(())
        } else {
            Err(io::Error::last_os_error())
        }
    }

    impl EpollSet {
        pub(super) fn new() -> io::Result<Self> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Self { epfd, entries: HashMap::new(), gen: 0, events: Vec::new() })
        }

        /// Start a new registration generation. Nothing is unregistered
        /// yet — fds absent from the new generation are `DEL`ed during
        /// the next [`Self::poll`], so a steady-state re-registration
        /// with identical interest costs zero syscalls.
        pub(super) fn clear(&mut self) {
            self.gen = self.gen.wrapping_add(1);
        }

        /// Declare interest for `fd` this generation. Last write wins
        /// if an fd is registered twice in one tick.
        pub(super) fn register(&mut self, fd: RawFd, token: usize, readable: bool, writable: bool) {
            let mut want = 0u32;
            if readable {
                want |= EPOLLIN;
            }
            if writable {
                want |= EPOLLOUT;
            }
            let gen = self.gen;
            self.entries
                .entry(fd)
                .and_modify(|e| {
                    e.token = token;
                    e.want = want;
                    e.gen = gen;
                })
                .or_insert(Entry { token, want, gen, kernel: None });
        }

        /// Reconcile kernel interest with the desired set, then wait.
        ///
        /// Reconciliation issues `EPOLL_CTL_DEL` for fds dropped this
        /// generation and `ADD`/`MOD` only where `(token, mask)`
        /// changed. Races with fd close/reuse are absorbed by the
        /// errno fallbacks (`MOD`→`ENOENT`→`ADD`, `ADD`→`EEXIST`→`MOD`);
        /// an fd the kernel still refuses is surfaced as a synthetic
        /// `invalid` readiness — the same contract `poll(2)` expresses
        /// with `POLLNVAL` — and the wait degrades to a zero-timeout
        /// sweep so the owner reaps it promptly.
        pub(super) fn poll(
            &mut self,
            timeout: Option<Duration>,
            out: &mut Vec<Readiness>,
        ) -> io::Result<usize> {
            let epfd = self.epfd;
            let gen = self.gen;
            let mut synthetic: Vec<Readiness> = Vec::new();
            self.entries.retain(|&fd, e| {
                if e.gen != gen {
                    if e.kernel.is_some() {
                        // Best effort: the fd may already be closed (the
                        // kernel then dropped it from the set itself).
                        let _ = ctl_op(epfd, EPOLL_CTL_DEL, fd, 0, 0);
                    }
                    return false;
                }
                if e.kernel == Some((e.token, e.want)) {
                    return true;
                }
                let first_op = if e.kernel.is_some() { EPOLL_CTL_MOD } else { EPOLL_CTL_ADD };
                let mut res = ctl_op(epfd, first_op, fd, e.want, e.token);
                if let Err(err) = &res {
                    match (first_op, err.raw_os_error()) {
                        // Mirror drift: kernel lost the fd (close+reuse).
                        (EPOLL_CTL_MOD, Some(ENOENT)) => {
                            res = ctl_op(epfd, EPOLL_CTL_ADD, fd, e.want, e.token);
                        }
                        // Mirror drift the other way: already registered.
                        (EPOLL_CTL_ADD, Some(EEXIST)) => {
                            res = ctl_op(epfd, EPOLL_CTL_MOD, fd, e.want, e.token);
                        }
                        _ => {}
                    }
                }
                match res {
                    Ok(()) => {
                        e.kernel = Some((e.token, e.want));
                        true
                    }
                    Err(_) => {
                        synthetic.push(Readiness {
                            token: e.token,
                            readable: false,
                            writable: false,
                            invalid: true,
                        });
                        false
                    }
                }
            });

            let timeout_ms =
                if synthetic.is_empty() { timeout_millis(timeout) } else { 0 };
            let want_events = self.entries.len().max(64);
            self.events.resize(want_events, EpollEvent { events: 0, data: 0 });
            let rc = loop {
                let rc = unsafe {
                    epoll_wait(
                        self.epfd,
                        self.events.as_mut_ptr(),
                        self.events.len() as c_int,
                        timeout_ms,
                    )
                };
                if rc >= 0 {
                    break rc as usize;
                }
                let err = io::Error::last_os_error();
                if err.kind() != io::ErrorKind::Interrupted {
                    return Err(err);
                }
            };
            for ev in &self.events[..rc] {
                let (events, data) = (ev.events, ev.data);
                out.push(Readiness {
                    token: data as usize,
                    readable: events & (EPOLLIN | EPOLLHUP | EPOLLERR) != 0,
                    writable: events & (EPOLLOUT | EPOLLERR) != 0,
                    invalid: false,
                });
            }
            out.extend_from_slice(&synthetic);
            Ok(out.len())
        }
    }

    impl Drop for EpollSet {
        fn drop(&mut self) {
            unsafe {
                close(self.epfd);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Poller: backend dispatch behind the three-method surface
// ---------------------------------------------------------------------------

#[derive(Debug)]
enum Imp {
    Poll(PollSet),
    #[cfg(target_os = "linux")]
    Epoll(epoll::EpollSet),
}

/// The reactor's interest set + wait primitive. Same three-method
/// surface regardless of backend: `clear` (new tick), `register`
/// (declare interest), `poll` (wait), then iterate [`Poller::ready`].
#[derive(Debug)]
pub struct Poller {
    imp: Imp,
    backend: PollerBackend,
    results: Vec<Readiness>,
}

impl Default for Poller {
    fn default() -> Self {
        Self::new()
    }
}

impl Poller {
    /// Poller on the resolved best backend (honoring `HLL_POLLER`).
    /// Infallible: if the preferred backend fails to initialize (e.g.
    /// `epoll_create1` hits the fd limit), falls back to `poll(2)`.
    pub fn new() -> Self {
        Self::with_backend(PollerBackend::Auto)
            .unwrap_or_else(|_| Self::poll_backed())
    }

    fn poll_backed() -> Self {
        Self {
            imp: Imp::Poll(PollSet::default()),
            backend: PollerBackend::Poll,
            results: Vec::new(),
        }
    }

    /// Poller on a specific backend (`Auto` resolves as documented on
    /// [`PollerBackend::resolve`]). Errors only if the resolved
    /// backend's kernel object cannot be created.
    pub fn with_backend(backend: PollerBackend) -> io::Result<Self> {
        match backend.resolve() {
            PollerBackend::Poll => Ok(Self::poll_backed()),
            #[cfg(target_os = "linux")]
            PollerBackend::Epoll => Ok(Self {
                imp: Imp::Epoll(epoll::EpollSet::new()?),
                backend: PollerBackend::Epoll,
                results: Vec::new(),
            }),
            // resolve() never returns Auto/Kqueue, nor Epoll off-Linux;
            // keep the fallback total anyway.
            _ => Ok(Self::poll_backed()),
        }
    }

    /// The concrete backend in use.
    pub fn backend(&self) -> PollerBackend {
        self.backend
    }

    /// Drop all registrations (start of a new tick). Under epoll this
    /// only opens a new generation — kernel interest is reconciled
    /// lazily at [`Self::poll`], so unchanged registrations cost no
    /// syscalls.
    pub fn clear(&mut self) {
        match &mut self.imp {
            Imp::Poll(p) => p.clear(),
            #[cfg(target_os = "linux")]
            Imp::Epoll(e) => e.clear(),
        }
    }

    /// Add `fd` to this tick's interest set under `token`. Registering
    /// with neither interest still reports errors/hangups (both
    /// backends always deliver those).
    pub fn register(&mut self, fd: RawFd, token: usize, readable: bool, writable: bool) {
        match &mut self.imp {
            Imp::Poll(p) => p.register(fd, token, readable, writable),
            #[cfg(target_os = "linux")]
            Imp::Epoll(e) => e.register(fd, token, readable, writable),
        }
    }

    /// Block until at least one registered fd is ready or `timeout`
    /// elapses (`None` = wait forever). Returns the ready count (0 =
    /// timeout). `EINTR` retries with the full timeout — callers poll
    /// on short ticks, so the drift is bounded and harmless.
    pub fn poll(&mut self, timeout: Option<Duration>) -> io::Result<usize> {
        self.results.clear();
        match &mut self.imp {
            Imp::Poll(p) => p.poll(timeout, &mut self.results),
            #[cfg(target_os = "linux")]
            Imp::Epoll(e) => e.poll(timeout, &mut self.results),
        }
    }

    /// Iterate the descriptors the last [`Self::poll`] reported ready.
    pub fn ready(&self) -> impl Iterator<Item = Readiness> + '_ {
        self.results.iter().copied()
    }
}

// ---------------------------------------------------------------------------
// Waker
// ---------------------------------------------------------------------------

/// The write end of a loop's self-pipe: wake the loop out of `poll`
/// from any thread. Wakes coalesce — if the pipe already holds an
/// unread byte the write would block and is dropped, which is exactly
/// the "a wake is already pending" case.
#[derive(Debug)]
pub struct Waker {
    tx: UnixStream,
}

impl Waker {
    pub fn wake(&self) {
        let _ = (&self.tx).write(&[1u8]);
    }
}

/// The read end of a loop's self-pipe; registered readable in the
/// loop's poll set every tick.
#[derive(Debug)]
pub struct WakeRx {
    rx: UnixStream,
}

impl WakeRx {
    pub fn as_raw_fd(&self) -> RawFd {
        self.rx.as_raw_fd()
    }

    /// Swallow all pending wake bytes (level-triggered polling would
    /// otherwise re-report forever).
    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        while matches!((&self.rx).read(&mut buf), Ok(n) if n > 0) {}
    }
}

/// A connected nonblocking self-pipe pair.
pub fn waker_pair() -> io::Result<(Waker, WakeRx)> {
    let (tx, rx) = UnixStream::pair()?;
    tx.set_nonblocking(true)?;
    rx.set_nonblocking(true)?;
    Ok((Waker { tx }, WakeRx { rx }))
}

// ---------------------------------------------------------------------------
// Tick profile
// ---------------------------------------------------------------------------

/// Per-event-loop tick profiler: where does a loop's wall time go?
///
/// Each tick splits into *wait* (blocked in the readiness syscall) and
/// *work* (dispatching ready connections, pumping subscribers,
/// reaping). Both land in lock-free [`LatencyHistogram`]s — once under
/// a per-loop `loop="N"` label, and again under a per-backend
/// `backend="epoll|poll"` label shared by every loop on that backend,
/// so poll-vs-epoll comparisons read one series per side instead of
/// joining per-loop series. Ready-event counts per tick land in a
/// third histogram pair, and a saturation gauge reports
/// `work / (work + wait)` in permille over an exponentially decayed
/// window — the "is this loop the bottleneck?" number the C100K roadmap
/// item gates on. Recording is a handful of relaxed atomics per tick;
/// only the loop thread calls [`TickProfile::tick`], scrapers read the
/// shared histograms.
///
/// [`LatencyHistogram`]: crate::obs::LatencyHistogram
#[derive(Debug)]
pub struct TickProfile {
    poll_wait_ns: std::sync::Arc<crate::obs::LatencyHistogram>,
    work_ns: std::sync::Arc<crate::obs::LatencyHistogram>,
    ready_events: std::sync::Arc<crate::obs::LatencyHistogram>,
    backend_poll_wait_ns: std::sync::Arc<crate::obs::LatencyHistogram>,
    backend_work_ns: std::sync::Arc<crate::obs::LatencyHistogram>,
    backend_ready_events: std::sync::Arc<crate::obs::LatencyHistogram>,
    saturation_permille: crate::obs::Gauge,
    /// Decayed accumulators (loop-thread-local; plain fields would do,
    /// but keeping the struct `Sync` costs nothing).
    busy_ns_acc: std::sync::atomic::AtomicU64,
    wait_ns_acc: std::sync::atomic::AtomicU64,
}

/// Decay window for the saturation gauge: once busy+wait exceeds ~5 s,
/// both halve, so the gauge tracks recent load instead of the lifetime
/// average.
const SATURATION_WINDOW_NS: u64 = 5_000_000_000;

impl TickProfile {
    /// Register this loop's tick series into `metrics` under a
    /// `loop="N"` label, plus the per-backend aggregate series under
    /// `backend="…"` (shared across loops on the same backend — the
    /// histograms are lock-free, concurrent recording is fine).
    pub fn register(
        metrics: &crate::obs::MetricsRegistry,
        loop_idx: usize,
        backend: PollerBackend,
    ) -> Self {
        let label = loop_label(loop_idx);
        let l = || Some(("loop", label.to_string()));
        let b = || Some(("backend", backend.label().to_string()));
        Self {
            poll_wait_ns: metrics.histogram("loop_poll_wait_ns", l()),
            work_ns: metrics.histogram("loop_work_ns", l()),
            ready_events: metrics.histogram("loop_ready_events", l()),
            backend_poll_wait_ns: metrics.histogram("loop_poll_wait_ns", b()),
            backend_work_ns: metrics.histogram("loop_work_ns", b()),
            backend_ready_events: metrics.histogram("loop_ready_events", b()),
            saturation_permille: metrics.gauge("loop_saturation_permille", l()),
            busy_ns_acc: std::sync::atomic::AtomicU64::new(0),
            wait_ns_acc: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Record one completed tick: `work` spent dispatching before the
    /// poll, `wait` blocked inside it, `ready` descriptors it returned.
    pub fn tick(&self, work: Duration, wait: Duration, ready: usize) {
        use std::sync::atomic::Ordering;
        let work_ns = work.as_nanos().min(u64::MAX as u128) as u64;
        let wait_ns = wait.as_nanos().min(u64::MAX as u128) as u64;
        self.work_ns.record(work_ns);
        self.poll_wait_ns.record(wait_ns);
        self.ready_events.record(ready as u64);
        self.backend_work_ns.record(work_ns);
        self.backend_poll_wait_ns.record(wait_ns);
        self.backend_ready_events.record(ready as u64);
        // Exponentially decayed busy fraction: halve both accumulators
        // whenever the window fills, then publish permille.
        let mut busy = self.busy_ns_acc.load(Ordering::Relaxed) + work_ns;
        let mut wait_acc = self.wait_ns_acc.load(Ordering::Relaxed) + wait_ns;
        if busy + wait_acc > SATURATION_WINDOW_NS {
            busy /= 2;
            wait_acc /= 2;
        }
        self.busy_ns_acc.store(busy, Ordering::Relaxed);
        self.wait_ns_acc.store(wait_acc, Ordering::Relaxed);
        let total = busy + wait_acc;
        if total > 0 {
            self.saturation_permille.set(busy * 1_000 / total);
        }
    }
}

/// Static label for a loop index ("0".."15", then "n" — metric labels
/// are `&'static str`, and 16 loops is already past the configured
/// maximum anyone runs).
fn loop_label(i: usize) -> &'static str {
    const LABELS: [&str; 16] = [
        "0", "1", "2", "3", "4", "5", "6", "7", "8", "9", "10", "11", "12", "13", "14", "15",
    ];
    LABELS.get(i).copied().unwrap_or("n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};
    use std::time::Duration;

    /// Every backend that actually works here, as live pollers.
    fn pollers() -> Vec<Poller> {
        PollerBackend::available()
            .iter()
            .map(|&b| {
                let p = Poller::with_backend(b).unwrap();
                assert_eq!(p.backend(), b);
                p
            })
            .collect()
    }

    #[test]
    fn backend_resolution_and_parsing() {
        assert_eq!(PollerBackend::parse("poll"), Some(PollerBackend::Poll));
        assert_eq!(PollerBackend::parse(" EPOLL "), Some(PollerBackend::Epoll));
        assert_eq!(PollerBackend::parse("kqueue"), Some(PollerBackend::Kqueue));
        assert_eq!(PollerBackend::parse("auto"), Some(PollerBackend::Auto));
        assert_eq!(PollerBackend::parse("io_uring"), None);
        // The kqueue stub always resolves to something available.
        assert!(PollerBackend::Kqueue.resolve().is_available());
        assert!(PollerBackend::best().is_available());
        // Poll is available everywhere and resolves to itself.
        assert_eq!(PollerBackend::Poll.resolve(), PollerBackend::Poll);
        #[cfg(target_os = "linux")]
        {
            assert_eq!(PollerBackend::best(), PollerBackend::Epoll);
            assert_eq!(PollerBackend::Epoll.resolve(), PollerBackend::Epoll);
        }
    }

    #[test]
    fn waker_crosses_poll_and_coalesces() {
        for mut poller in pollers() {
            let (waker, rx) = waker_pair().unwrap();
            // No wake pending: poll times out.
            poller.clear();
            poller.register(rx.as_raw_fd(), 1, true, false);
            assert_eq!(poller.poll(Some(Duration::from_millis(10))).unwrap(), 0);
            // Wakes (from another thread) make the pipe readable; repeated
            // wakes coalesce and drain clears them.
            let t = std::thread::spawn(move || {
                for _ in 0..100 {
                    waker.wake();
                }
                waker
            });
            let _waker = t.join().unwrap();
            poller.clear();
            poller.register(rx.as_raw_fd(), 1, true, false);
            assert_eq!(poller.poll(Some(Duration::from_secs(5))).unwrap(), 1);
            let ready: Vec<Readiness> = poller.ready().collect();
            assert_eq!(ready.len(), 1);
            assert_eq!(ready[0].token, 1);
            assert!(ready[0].readable);
            rx.drain();
            poller.clear();
            poller.register(rx.as_raw_fd(), 1, true, false);
            assert_eq!(poller.poll(Some(Duration::from_millis(10))).unwrap(), 0, "drained");
        }
    }

    #[test]
    fn poller_reports_tcp_readability_and_writability() {
        for mut poller in pollers() {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();

            // Nothing pending: the listener is not readable.
            poller.clear();
            poller.register(listener.as_raw_fd(), 7, true, false);
            assert_eq!(poller.poll(Some(Duration::from_millis(10))).unwrap(), 0);

            // A pending connection makes it readable.
            let client = TcpStream::connect(addr).unwrap();
            poller.clear();
            poller.register(listener.as_raw_fd(), 7, true, false);
            assert_eq!(poller.poll(Some(Duration::from_secs(5))).unwrap(), 1);
            assert!(poller.ready().any(|r| r.token == 7 && r.readable));
            let (server_side, _) = listener.accept().unwrap();

            // A fresh connected socket: writable, not readable.
            poller.clear();
            poller.register(client.as_raw_fd(), 8, true, true);
            assert_eq!(poller.poll(Some(Duration::from_secs(5))).unwrap(), 1);
            let r: Vec<Readiness> = poller.ready().collect();
            assert!(r[0].writable && !r[0].readable);

            // Peer data arrives: readable too.
            (&server_side).write_all(&[9u8; 4]).unwrap();
            poller.clear();
            poller.register(client.as_raw_fd(), 8, true, false);
            assert_eq!(poller.poll(Some(Duration::from_secs(5))).unwrap(), 1);
            assert!(poller.ready().any(|r| r.token == 8 && r.readable));
        }
    }

    /// Interest dropped for one tick must actually stop event delivery
    /// (the epoll backend has to issue `EPOLL_CTL_DEL`, not just skip
    /// the fd in userspace), and re-registering must resume it.
    #[test]
    fn dropped_registration_stops_delivery() {
        for mut poller in pollers() {
            let (a_far, a) = UnixStream::pair().unwrap();
            let (_b_far, b) = UnixStream::pair().unwrap();
            a.set_nonblocking(true).unwrap();
            b.set_nonblocking(true).unwrap();
            (&a_far).write_all(&[1u8; 8]).unwrap();

            // Both registered: only `a` (with pending data) is ready.
            poller.clear();
            poller.register(a.as_raw_fd(), 1, true, false);
            poller.register(b.as_raw_fd(), 2, true, false);
            assert_eq!(poller.poll(Some(Duration::from_secs(5))).unwrap(), 1);
            assert!(poller.ready().any(|r| r.token == 1 && r.readable));

            // `a` dropped from the set: its still-pending data must not
            // surface.
            poller.clear();
            poller.register(b.as_raw_fd(), 2, true, false);
            assert_eq!(poller.poll(Some(Duration::from_millis(20))).unwrap(), 0);

            // Re-registered (fresh token): delivery resumes.
            poller.clear();
            poller.register(a.as_raw_fd(), 9, true, false);
            poller.register(b.as_raw_fd(), 2, true, false);
            assert_eq!(poller.poll(Some(Duration::from_secs(5))).unwrap(), 1);
            assert!(poller.ready().any(|r| r.token == 9 && r.readable));
        }
    }

    /// Interest flips (the server's backpressure mechanism) must
    /// translate to updated kernel state under every backend.
    #[test]
    fn interest_flip_changes_reported_events() {
        for mut poller in pollers() {
            let (far, near) = UnixStream::pair().unwrap();
            near.set_nonblocking(true).unwrap();
            (&far).write_all(&[7u8; 4]).unwrap();

            // Readable+writable: both reported.
            poller.clear();
            poller.register(near.as_raw_fd(), 3, true, true);
            assert_eq!(poller.poll(Some(Duration::from_secs(5))).unwrap(), 1);
            let r: Vec<Readiness> = poller.ready().collect();
            assert!(r[0].readable && r[0].writable);

            // Flip to write-only: pending read data must not surface.
            poller.clear();
            poller.register(near.as_raw_fd(), 3, false, true);
            assert_eq!(poller.poll(Some(Duration::from_secs(5))).unwrap(), 1);
            let r: Vec<Readiness> = poller.ready().collect();
            assert!(r[0].writable && !r[0].readable);

            // Flip back to read-only.
            poller.clear();
            poller.register(near.as_raw_fd(), 3, true, false);
            assert_eq!(poller.poll(Some(Duration::from_secs(5))).unwrap(), 1);
            let r: Vec<Readiness> = poller.ready().collect();
            assert!(r[0].readable && !r[0].writable);
        }
    }

    /// A closed-then-reused registration slot must not leak stale
    /// kernel state: dropping the fd's registration after close and
    /// registering a fresh fd (possibly with the same number) works.
    #[test]
    fn close_and_reuse_cycle_is_absorbed() {
        for mut poller in pollers() {
            for round in 0..4 {
                let (far, near) = UnixStream::pair().unwrap();
                near.set_nonblocking(true).unwrap();
                (&far).write_all(&[round as u8 + 1; 2]).unwrap();
                poller.clear();
                poller.register(near.as_raw_fd(), 100 + round, true, false);
                assert_eq!(poller.poll(Some(Duration::from_secs(5))).unwrap(), 1);
                assert!(poller.ready().any(|r| r.token == 100 + round && r.readable));
                // `near`/`far` drop here: the fd closes while still in
                // the kernel set; next round likely reuses the number.
            }
            // After the churn an empty set still polls cleanly.
            poller.clear();
            assert_eq!(poller.poll(Some(Duration::from_millis(5))).unwrap(), 0);
        }
    }
}
