//! Blocking client for the sketch server: one method per RPC plus batch
//! pipelining for ingest-heavy producers.
//!
//! [`SketchClient::pipeline_insert`] writes a whole flight of
//! `INSERT_BATCH` frames before reading the first reply, amortizing the
//! round-trip latency that dominates small-batch throughput over real
//! sockets (the `server_roundtrip` bench measures the difference
//! against in-process ingest).
//!
//! Against a read-only replica ([`crate::replica::FollowerServer`]),
//! query methods work unchanged while every mutating call fails with
//! [`ClientError::Remote`] carrying [`ErrorCode::ReadOnly`] — route
//! writes to the primary.
//!
//! Calls block forever by default (source-compatible with every
//! existing caller); [`SketchClient::set_read_timeout`] /
//! [`SketchClient::set_write_timeout`] (or
//! [`SketchClient::connect_with_timeouts`]) bound them, surfacing
//! expiry as a typed [`ClientError::Timeout`] that poisons the
//! connection — a hung server then costs the caller a bounded wait and
//! a reconnect, not a parked thread.
//!
//! Tracing is opt-in per connection:
//! [`SketchClient::negotiate_tracing`] probes the server with a
//! `TraceDump` (servers predating it answer a typed error and the
//! connection keeps serving untraced), after which ingest calls stamp a
//! 16-byte trace context on their frames and
//! [`SketchClient::trace_dump`] /
//! [`SketchClient::trace_dump_text`] read the server's flight
//! recorder.

use std::io::{self, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use super::protocol::{
    encode_insert_batch, encode_insert_batch_traced, read_response, ErrorCode, EvictPolicy,
    ProtocolError, Request, Response, StatsSummary, MAX_PAYLOAD,
};
use crate::hll::HllSketch;
use crate::obs::trace::{next_trace_id, render_events, Span, Stage, TraceEvent, TRACE_CTX_LEN};

/// Errors from client calls.
#[derive(Debug)]
pub enum ClientError {
    /// Socket-level failure.
    Io(io::Error),
    /// The server's bytes did not parse as a protocol frame.
    Protocol(ProtocolError),
    /// The server answered with a typed `ERROR` frame.
    Remote { code: ErrorCode, message: String },
    /// The server answered with the wrong (but valid) response kind.
    Unexpected { wanted: &'static str, got: &'static str },
    /// A mid-pipeline failure left unread replies on the wire; the
    /// connection is desynchronized. Reconnect to recover.
    Poisoned,
    /// The request payload would exceed the protocol's
    /// [`MAX_PAYLOAD`] frame cap; caught client-side before any bytes
    /// hit the wire (the server would reject it and drop the connection).
    TooLarge { bytes: u64 },
    /// A configured socket timeout ([`SketchClient::set_read_timeout`] /
    /// [`SketchClient::set_write_timeout`]) expired mid-call. The
    /// connection is poisoned afterwards — the late reply may still
    /// arrive and would pair with the wrong request — so reconnect.
    /// Never raised unless a timeout was explicitly configured
    /// (defaults are off, matching the old always-blocking client).
    Timeout,
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "client io error: {e}"),
            ClientError::Protocol(e) => write!(f, "protocol error: {e}"),
            ClientError::Remote { code, message } => {
                write!(f, "server error ({code:?}): {message}")
            }
            ClientError::Unexpected { wanted, got } => {
                write!(f, "expected {wanted} response, got {got}")
            }
            ClientError::Poisoned => {
                write!(f, "connection desynchronized by an earlier pipelined failure; reconnect")
            }
            ClientError::TooLarge { bytes } => {
                write!(f, "request payload of {bytes} bytes exceeds the {MAX_PAYLOAD}-byte frame cap")
            }
            ClientError::Timeout => {
                write!(f, "socket timeout expired waiting on the server; reconnect")
            }
        }
    }
}

impl std::error::Error for ClientError {}

impl From<io::Error> for ClientError {
    fn from(e: io::Error) -> Self {
        ClientError::Io(e)
    }
}

impl From<ProtocolError> for ClientError {
    fn from(e: ProtocolError) -> Self {
        ClientError::Protocol(e)
    }
}

/// Batches per pipelined flight. Each reply frame is 16 bytes, so one
/// window leaves at most ~8 KiB of un-read replies in flight — far
/// below any platform's socket buffers, which is what makes
/// [`SketchClient::pipeline_insert`] deadlock-free.
pub const PIPELINE_WINDOW: usize = 512;

/// A blocking connection to a [`super::SketchServer`].
pub struct SketchClient {
    stream: TcpStream,
    /// Set when a mid-pipeline failure leaves unread replies on the
    /// wire: request/reply pairing is gone, so every later call would
    /// read some earlier request's reply. Once set, all calls fail with
    /// [`ClientError::Poisoned`].
    poisoned: bool,
    /// Set by a successful [`SketchClient::negotiate_tracing`]: ingest
    /// frames then carry a trailing 16-byte trace context. Off by
    /// default — a pre-tracing server's strict payload decode would
    /// reject the longer frames.
    tracing: bool,
}

/// A socket error that means "the configured timeout expired", on
/// either platform convention (unix reports `WouldBlock`, Windows
/// `TimedOut`).
fn is_timeout(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

impl SketchClient {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Self, ClientError> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(Self { stream, poisoned: false, tracing: false })
    }

    /// As [`SketchClient::connect`], with read/write timeouts applied
    /// before the first RPC — the "a hung server must not block my
    /// caller forever" constructor. The TCP connect itself is bounded
    /// by the read timeout too (a black-holed address otherwise blocks
    /// in the OS connect for minutes before any socket timeout could
    /// apply); a connect that exceeds it fails with
    /// [`ClientError::Timeout`].
    pub fn connect_with_timeouts(
        addr: impl ToSocketAddrs,
        read: Option<Duration>,
        write: Option<Duration>,
    ) -> Result<Self, ClientError> {
        let stream = match read {
            None => TcpStream::connect(addr)?,
            Some(bound) => {
                let mut last: Option<io::Error> = None;
                let mut connected = None;
                for candidate in addr.to_socket_addrs().map_err(ClientError::Io)? {
                    match TcpStream::connect_timeout(&candidate, bound) {
                        Ok(s) => {
                            connected = Some(s);
                            break;
                        }
                        Err(e) => last = Some(e),
                    }
                }
                match (connected, last) {
                    (Some(s), _) => s,
                    (None, Some(e)) if is_timeout(&e) => return Err(ClientError::Timeout),
                    (None, Some(e)) => return Err(ClientError::Io(e)),
                    (None, None) => {
                        return Err(ClientError::Io(io::Error::new(
                            io::ErrorKind::InvalidInput,
                            "address resolved to no socket addresses",
                        )))
                    }
                }
            }
        };
        stream.set_nodelay(true).ok();
        let mut client = Self { stream, poisoned: false, tracing: false };
        client.set_read_timeout(read)?;
        client.set_write_timeout(write)?;
        Ok(client)
    }

    /// Bound how long any call waits on the server's reply. `None`
    /// (the default) blocks forever — source-compatible with every
    /// existing caller. With a bound set, an expiry surfaces as
    /// [`ClientError::Timeout`] and poisons the connection (the late
    /// reply would pair with the wrong request): reconnect to recover.
    pub fn set_read_timeout(&mut self, timeout: Option<Duration>) -> Result<(), ClientError> {
        self.stream.set_read_timeout(timeout).map_err(ClientError::Io)
    }

    /// Bound how long any call waits for the server to accept request
    /// bytes (a server that stopped draining its socket). Semantics as
    /// [`SketchClient::set_read_timeout`].
    pub fn set_write_timeout(&mut self, timeout: Option<Duration>) -> Result<(), ClientError> {
        self.stream.set_write_timeout(timeout).map_err(ClientError::Io)
    }

    fn check_sync(&self) -> Result<(), ClientError> {
        if self.poisoned {
            return Err(ClientError::Poisoned);
        }
        Ok(())
    }

    /// Reject a payload the server's frame cap would refuse, before any
    /// bytes are written (the server answers Oversize and drops the
    /// connection, which would surface here as a raw Io error).
    fn check_payload(bytes: u64) -> Result<(), ClientError> {
        if bytes > MAX_PAYLOAD as u64 {
            return Err(ClientError::TooLarge { bytes });
        }
        Ok(())
    }

    /// Write raw frame bytes, mapping a write-timeout expiry to the
    /// typed [`ClientError::Timeout`] (and poisoning: a partial frame
    /// may be on the wire).
    fn write_wire(&mut self, bytes: &[u8]) -> Result<(), ClientError> {
        match self.stream.write_all(bytes) {
            Ok(()) => Ok(()),
            Err(e) if is_timeout(&e) => {
                self.poisoned = true;
                Err(ClientError::Timeout)
            }
            Err(e) => Err(ClientError::Io(e)),
        }
    }

    fn send(&mut self, req: &Request) -> Result<(), ClientError> {
        self.check_sync()?;
        self.write_wire(&req.encode())
    }

    fn recv(&mut self) -> Result<Response, ClientError> {
        self.check_sync()?;
        match read_response(&mut self.stream) {
            Ok(Response::Error { code, message }) => Err(ClientError::Remote { code, message }),
            Ok(other) => Ok(other),
            Err(ProtocolError::Io(e)) if is_timeout(&e) => {
                // The reply (or its tail) may still arrive later and
                // would desynchronize request/reply pairing.
                self.poisoned = true;
                Err(ClientError::Timeout)
            }
            Err(e) => Err(e.into()),
        }
    }

    fn call(&mut self, req: &Request) -> Result<Response, ClientError> {
        self.send(req)?;
        self.recv()
    }

    /// Liveness check.
    pub fn ping(&mut self) -> Result<(), ClientError> {
        match self.call(&Request::Ping)? {
            Response::Pong => Ok(()),
            other => Err(unexpected("Pong", &other)),
        }
    }

    /// Probe whether the server understands tracing, and turn it on for
    /// this connection if so. Sends a `TraceDump`: a tracing-aware
    /// server answers with its event ring (any size, including empty);
    /// an older server answers a typed `Malformed` "unknown opcode"
    /// error *and keeps the connection serving* (unknown opcodes are a
    /// payload-decode failure, not a framing one), so `Ok(false)` here
    /// means "old peer, staying untraced" with no reconnect needed.
    /// Transport-level failures propagate unchanged.
    pub fn negotiate_tracing(&mut self) -> Result<bool, ClientError> {
        match self.call(&Request::TraceDump) {
            Ok(Response::TraceEvents { .. }) => {
                self.tracing = true;
                Ok(true)
            }
            Ok(other) => Err(unexpected("TraceEvents", &other)),
            Err(ClientError::Remote { .. }) => {
                self.tracing = false;
                Ok(false)
            }
            Err(e) => Err(e),
        }
    }

    /// Whether a successful [`Self::negotiate_tracing`] armed trace
    /// stamping on this connection.
    pub fn tracing_enabled(&self) -> bool {
        self.tracing
    }

    /// Ingest one keyed batch; returns the number of words the server
    /// accepted. When tracing is negotiated the frame carries a fresh
    /// trace context (see [`Self::insert_batch_traced`] to learn the
    /// stamped id).
    pub fn insert_batch(&mut self, key: u64, words: &[u32]) -> Result<u64, ClientError> {
        self.insert_batch_traced(key, words).map(|(words, _)| words)
    }

    /// As [`Self::insert_batch`], also returning the trace id stamped
    /// on the frame (`0` when tracing is off) so the caller can later
    /// correlate it against [`Self::trace_dump`] output.
    pub fn insert_batch_traced(
        &mut self,
        key: u64,
        words: &[u32],
    ) -> Result<(u64, u64), ClientError> {
        self.check_sync()?;
        let trace_id = if self.tracing { next_trace_id() } else { 0 };
        let extra = if trace_id != 0 { TRACE_CTX_LEN as u64 } else { 0 };
        Self::check_payload(12 + words.len() as u64 * 4 + extra)?;
        {
            // The client_send span brackets encode + socket write; it
            // lands in *this process's* recorder (useful when client
            // and server share a process, as in tests and benches).
            let _span =
                Span::enter(Stage::ClientSend, trace_id).with_payload(words.len() as u64);
            if trace_id != 0 {
                self.write_wire(&encode_insert_batch_traced(key, words, trace_id))?;
            } else {
                self.write_wire(&encode_insert_batch(key, words))?;
            }
        }
        match self.recv()? {
            Response::Ingested { words } => Ok((words, trace_id)),
            other => Err(unexpected("Ingested", &other)),
        }
    }

    /// Pipelined ingest: write a whole window of batch frames, then read
    /// the window's replies — one round trip per window instead of one
    /// per batch. Returns the total words accepted.
    ///
    /// The window is bounded ([`PIPELINE_WINDOW`] batches) so the
    /// replies outstanding at any moment stay far below a socket
    /// buffer; an unbounded flight could deadlock against the server
    /// through TCP flow control (server blocked writing replies nobody
    /// reads, client blocked writing requests nobody reads).
    pub fn pipeline_insert(&mut self, batches: &[(u64, Vec<u32>)]) -> Result<u64, ClientError> {
        self.check_sync()?;
        let extra = if self.tracing { TRACE_CTX_LEN as u64 } else { 0 };
        for (_, words) in batches {
            Self::check_payload(12 + words.len() as u64 * 4 + extra)?;
        }
        let mut total = 0u64;
        for window in batches.chunks(PIPELINE_WINDOW) {
            let mut wire = Vec::new();
            for (key, words) in window {
                if self.tracing {
                    // Each batch in the flight gets its own trace id so
                    // a server-side dump attributes spans per batch,
                    // not per window.
                    wire.extend_from_slice(&encode_insert_batch_traced(
                        *key,
                        words,
                        next_trace_id(),
                    ));
                } else {
                    wire.extend_from_slice(&encode_insert_batch(*key, words));
                }
            }
            self.write_wire(&wire)?;
            for i in 0..window.len() {
                let replies_outstanding = window.len() - i - 1;
                match self.recv() {
                    Ok(Response::Ingested { words }) => total += words,
                    Ok(other) => {
                        // A valid but wrong-typed frame mid-flight: the
                        // request/reply pairing is no longer trustworthy.
                        self.poisoned = true;
                        return Err(unexpected("Ingested", &other));
                    }
                    Err(e) => {
                        // A failed reply with more replies still on the
                        // wire leaves the stream desynchronized; a
                        // failure on the window's last reply does not.
                        if replies_outstanding > 0 {
                            self.poisoned = true;
                        }
                        return Err(e);
                    }
                }
            }
        }
        Ok(total)
    }

    /// Per-key distinct estimate; `Ok(None)` for unknown keys.
    pub fn estimate(&mut self, key: u64) -> Result<Option<f64>, ClientError> {
        match self.call(&Request::Estimate { key })? {
            Response::Estimate(v) => Ok(v),
            other => Err(unexpected("Estimate", &other)),
        }
    }

    /// Distinct count across all keys (if the server's registry tracks it).
    pub fn global_estimate(&mut self) -> Result<Option<f64>, ClientError> {
        match self.call(&Request::GlobalEstimate)? {
            Response::GlobalEstimate(v) => Ok(v),
            other => Err(unexpected("GlobalEstimate", &other)),
        }
    }

    /// Merge a locally built sketch into `key` server-side (wire format
    /// v2, so the hash seed rides along and mismatches are rejected).
    pub fn merge_sketch(&mut self, key: u64, sketch: &HllSketch) -> Result<(), ClientError> {
        self.merge_sketch_bytes(key, &sketch.to_bytes())
    }

    /// As [`Self::merge_sketch`], for bytes already in wire format v2.
    pub fn merge_sketch_bytes(&mut self, key: u64, bytes: &[u8]) -> Result<(), ClientError> {
        Self::check_payload(12 + bytes.len() as u64)?;
        match self.call(&Request::MergeSketch { key, bytes: bytes.to_vec() })? {
            Response::Merged => Ok(()),
            other => Err(unexpected("Merged", &other)),
        }
    }

    /// Registry accounting totals.
    pub fn stats(&mut self) -> Result<StatsSummary, ClientError> {
        match self.call(&Request::Stats)? {
            Response::Stats(s) => Ok(s),
            other => Err(unexpected("Stats", &other)),
        }
    }

    /// Run an eviction policy server-side; returns the number of keys
    /// dropped.
    pub fn evict(&mut self, policy: EvictPolicy) -> Result<u64, ClientError> {
        match self.call(&Request::Evict(policy))? {
            Response::Evicted { keys } => Ok(keys),
            other => Err(unexpected("Evicted", &other)),
        }
    }

    /// Ask the server to snapshot its registry to its configured path;
    /// returns `(keys, file_bytes)` persisted.
    pub fn snapshot(&mut self) -> Result<(u64, u64), ClientError> {
        match self.call(&Request::Snapshot)? {
            Response::SnapshotDone { keys, bytes } => Ok((keys, bytes)),
            other => Err(unexpected("SnapshotDone", &other)),
        }
    }

    /// Scrape the server's metrics exposition: a versioned
    /// `# hll-metrics v1` text of `name{label="v"} value` lines
    /// (per-opcode latency quantiles, tick profiles, tier gauges,
    /// replication lag). Served by primaries and read-only replicas
    /// alike.
    pub fn metrics_dump(&mut self) -> Result<String, ClientError> {
        match self.call(&Request::MetricsDump)? {
            Response::MetricsText(text) => Ok(text),
            other => Err(unexpected("MetricsText", &other)),
        }
    }

    /// Snapshot the server's flight recorder: its most recent trace
    /// events, merged across threads and sorted by timestamp. Works
    /// without [`Self::negotiate_tracing`] (the dump itself is the
    /// negotiation probe) and on read-only replicas.
    pub fn trace_dump(&mut self) -> Result<Vec<TraceEvent>, ClientError> {
        match self.call(&Request::TraceDump)? {
            Response::TraceEvents { events } => Ok(events),
            other => Err(unexpected("TraceEvents", &other)),
        }
    }

    /// As [`Self::trace_dump`], rendered as one human-readable line per
    /// event (`ts_ns kind stage trace_id payload`).
    pub fn trace_dump_text(&mut self) -> Result<String, ClientError> {
        Ok(render_events(&self.trace_dump()?))
    }
}

fn unexpected(wanted: &'static str, got: &Response) -> ClientError {
    ClientError::Unexpected { wanted, got: got.label() }
}
