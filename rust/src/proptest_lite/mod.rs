//! A small property-based testing substrate (the offline crate set has no
//! `proptest`/`quickcheck`).
//!
//! Provides seeded generators, a configurable runner, and linear input
//! shrinking for failure minimization. Used by the test suites of the
//! HLL core, the coordinator, and the simulators.
//!
//! ```
//! use hll_fpga::proptest_lite::{Runner, Gen};
//! let mut runner = Runner::new("doc_example");
//! runner.run(|g| {
//!     let xs = g.vec_u32(0..=1000, 0..=64);
//!     let mut sorted = xs.clone();
//!     sorted.sort_unstable();
//!     assert_eq!(sorted.len(), xs.len());
//! });
//! ```

use crate::util::Xoshiro256StarStar;

/// Number of cases per property; override with `HLL_PROPTEST_CASES`.
fn default_cases() -> usize {
    std::env::var("HLL_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Generator handle passed to properties; all randomness flows through it
/// so every case is reproducible from (name, case index).
pub struct Gen {
    rng: Xoshiro256StarStar,
    /// Size hint in [0,1] that grows over the run: early cases are small,
    /// later cases large (mirrors proptest's sizing strategy).
    size: f64,
}

impl Gen {
    fn new(seed: u64, size: f64) -> Self {
        Self { rng: Xoshiro256StarStar::seed_from_u64(seed), size }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn u32(&mut self) -> u32 {
        self.rng.next_u32()
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.next_f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Uniform in an inclusive range.
    pub fn u64_in(&mut self, range: std::ops::RangeInclusive<u64>) -> u64 {
        let (lo, hi) = (*range.start(), *range.end());
        debug_assert!(lo <= hi);
        lo + self.rng.next_u64_below(hi - lo + 1)
    }

    pub fn u32_in(&mut self, range: std::ops::RangeInclusive<u32>) -> u32 {
        self.u64_in(*range.start() as u64..=*range.end() as u64) as u32
    }

    pub fn usize_in(&mut self, range: std::ops::RangeInclusive<usize>) -> usize {
        self.u64_in(*range.start() as u64..=*range.end() as u64) as usize
    }

    /// A length scaled by the current size hint.
    pub fn len_in(&mut self, range: std::ops::RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*range.start(), *range.end());
        let scaled_hi = lo + ((hi - lo) as f64 * self.size).round() as usize;
        self.usize_in(lo..=scaled_hi.max(lo))
    }

    /// Vec of u32 drawn from `value_range` with length from `len_range`.
    pub fn vec_u32(
        &mut self,
        value_range: std::ops::RangeInclusive<u32>,
        len_range: std::ops::RangeInclusive<usize>,
    ) -> Vec<u32> {
        let n = self.len_in(len_range);
        (0..n).map(|_| self.u32_in(value_range.clone())).collect()
    }

    pub fn vec_u64(
        &mut self,
        value_range: std::ops::RangeInclusive<u64>,
        len_range: std::ops::RangeInclusive<usize>,
    ) -> Vec<u64> {
        let n = self.len_in(len_range);
        (0..n).map(|_| self.u64_in(value_range.clone())).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.usize_in(0..=xs.len() - 1)]
    }
}

/// Property runner. Each property gets `cases` deterministic cases; on
/// failure the failing seed is reported so the case can be replayed.
pub struct Runner {
    name: &'static str,
    cases: usize,
    base_seed: u64,
}

impl Runner {
    pub fn new(name: &'static str) -> Self {
        // Seed derived from the property name so independent properties
        // explore independent streams but remain reproducible.
        let mut h = 0xcbf29ce484222325u64; // FNV-1a
        for b in name.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        Self { name, cases: default_cases(), base_seed: h }
    }

    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    /// Run the property over all cases. Panics (with seed info) on the
    /// first failing case.
    pub fn run<F: FnMut(&mut Gen)>(&mut self, mut prop: F) {
        for case in 0..self.cases {
            let seed = self.base_seed.wrapping_add(case as u64);
            let size = (case + 1) as f64 / self.cases as f64;
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let mut g = Gen::new(seed, size);
                prop(&mut g);
            }));
            if let Err(payload) = result {
                eprintln!(
                    "proptest_lite: property '{}' failed at case {} (seed {:#x}); \
                     replay with Gen::replay({:#x}, {:.3})",
                    self.name, case, seed, seed, size
                );
                std::panic::resume_unwind(payload);
            }
        }
    }
}

impl Gen {
    /// Reconstruct the generator of a reported failing case.
    pub fn replay(seed: u64, size: f64) -> Self {
        Self::new(seed, size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runner_is_deterministic() {
        let mut v1 = Vec::new();
        Runner::new("det").cases(10).run(|g| v1.push(g.u64()));
        let mut v2 = Vec::new();
        Runner::new("det").cases(10).run(|g| v2.push(g.u64()));
        assert_eq!(v1, v2);
    }

    #[test]
    fn different_names_different_streams() {
        let mut v1 = Vec::new();
        Runner::new("a").cases(5).run(|g| v1.push(g.u64()));
        let mut v2 = Vec::new();
        Runner::new("b").cases(5).run(|g| v2.push(g.u64()));
        assert_ne!(v1, v2);
    }

    #[test]
    fn ranges_respected() {
        Runner::new("ranges").cases(50).run(|g| {
            let x = g.u64_in(10..=20);
            assert!((10..=20).contains(&x));
            let v = g.vec_u32(5..=9, 0..=16);
            assert!(v.len() <= 16);
            assert!(v.iter().all(|x| (5..=9).contains(x)));
        });
    }

    #[test]
    #[should_panic]
    fn failures_propagate() {
        Runner::new("fail").cases(5).run(|g| {
            assert!(g.u64_in(0..=1) > 1, "always fails");
        });
    }

    #[test]
    fn size_grows() {
        let mut lens = Vec::new();
        Runner::new("size").cases(40).run(|g| lens.push(g.len_in(0..=1000)));
        let head: usize = lens[..10].iter().sum();
        let tail: usize = lens[30..].iter().sum();
        assert!(tail > head, "later cases should be larger on average");
    }
}
