//! Conflict-free primary→follower replication.
//!
//! HLL's core algebraic asset — registers only ever move up, and merge
//! is a bucket-wise max (commutative, associative, idempotent) — is the
//! same property the source paper leans on to fold parallel FPGA
//! pipelines into one sketch (Fig 3), and it makes distributed
//! cardinality state **conflict-free by construction**: any
//! interleaving of deltas, replays after a reconnect, or a full image
//! applied over partial state all converge to the same registers.
//! This module turns that property into a serving feature: a follower
//! node answers `Estimate`/`GlobalEstimate` bit-exactly equal to its
//! primary once it has drained the stream.
//!
//! # Pieces
//!
//! * [`ReplicationLog`] (+ [`ReplicationConfig`]) — primary-side:
//!   dirty-key drains ([`crate::registry::SketchRegistry::drain_dirty_sketches`])
//!   sealed into ordered `Arc`-shared batches, retained in a
//!   byte-bounded ring for cursor resume;
//! * the capture thread and subscriber streaming live in
//!   [`crate::server`] (`ServerConfig::replication` turns a
//!   [`crate::server::SketchServer`] into a primary; `SUBSCRIBE` flips
//!   a connection into a replication stream with ack-window
//!   backpressure);
//! * [`FollowerServer`] (+ [`FollowerConfig`]) — follower-side:
//!   subscribe / apply / ack, cursor resume across kills and
//!   reconnects ([`ReplicaCursor`]: the primary log's incarnation
//!   epoch + last applied seq, so a cursor from a restarted primary's
//!   previous log can never alias into the new numbering), full-sync
//!   fallback for stale or cross-epoch cursors, read-only serving of
//!   the replicated registry.
//!
//! # Semantics and limits
//!
//! Replication ships *additions*: per-key max-merge frames and full
//! images. Evictions do **not** propagate — a follower keeps serving
//! keys the primary has dropped. For append-mostly flow counting this
//! is exactly right; an evicting primary (TTL sweeper, budget) paired
//! with a follower will diverge on evicted keys until the follower's
//! next full sync — and a primary that evicts a key and then re-ingests
//! it under the same name diverges on that key (the follower max-merges
//! old and new state). Tombstone frames are the queued follow-on
//! (ROADMAP). A `FULL_SYNC` body is one in-band frame, so registries
//! whose snapshot image exceeds the frame cap
//! ([`crate::server::MAX_PAYLOAD`]) must bootstrap followers from a
//! snapshot file instead.
//!
//! ```no_run
//! use std::sync::Arc;
//! use std::time::Duration;
//! use hll_fpga::registry::{RegistryConfig, SketchRegistry};
//! use hll_fpga::replica::{FollowerConfig, FollowerServer, ReplicationConfig};
//! use hll_fpga::server::{ServerConfig, SketchClient, SketchServer};
//!
//! // Primary: a normal server with replication turned on.
//! let primary_reg = SketchRegistry::shared(RegistryConfig::default()).unwrap();
//! let primary = SketchServer::start(
//!     "127.0.0.1:0",
//!     primary_reg.clone(),
//!     ServerConfig { replication: Some(ReplicationConfig::default()), ..Default::default() },
//! )
//! .unwrap();
//!
//! // Follower: replicates the primary, serves reads, rejects writes.
//! let follower_reg = SketchRegistry::shared(RegistryConfig::default()).unwrap();
//! let follower = FollowerServer::start(
//!     "127.0.0.1:0",
//!     primary.local_addr(),
//!     follower_reg,
//!     FollowerConfig::default(),
//! )
//! .unwrap();
//!
//! let mut producer = SketchClient::connect(primary.local_addr()).unwrap();
//! producer.insert_batch(42, &[1, 2, 3]).unwrap();
//! // ... after the stream drains, a client of `follower.local_addr()`
//! // answers the same estimates as the primary, bit-exactly.
//! ```

pub mod follower;
pub mod log;

pub use follower::{FollowerConfig, FollowerServer, FollowerStats};
pub use log::{
    LogRead, ReplicaCursor, ReplicationConfig, ReplicationLog, ReplicationLogStats,
    SealedBatch,
};
