//! Conflict-free primary→follower replication.
//!
//! HLL's core algebraic asset — registers only ever move up, and merge
//! is a bucket-wise max (commutative, associative, idempotent) — is the
//! same property the source paper leans on to fold parallel FPGA
//! pipelines into one sketch (Fig 3), and it makes distributed
//! cardinality state **conflict-free by construction**: any
//! interleaving of deltas, replays after a reconnect, or a full image
//! applied over partial state all converge to the same registers.
//! This module turns that property into a serving feature: a follower
//! node answers `Estimate`/`GlobalEstimate` bit-exactly equal to its
//! primary once it has drained the stream.
//!
//! # Pieces
//!
//! * [`ReplicationLog`] (+ [`ReplicationConfig`]) — primary-side:
//!   typed dirty drains
//!   ([`crate::registry::SketchRegistry::drain_dirty_deltas`]: register
//!   diffs / full sketches / eviction tombstones) sealed into ordered
//!   `Arc`-shared batches, retained in a byte-bounded ring for cursor
//!   resume;
//! * the capture thread and subscriber streaming live in
//!   [`crate::server`] (`ServerConfig::replication` turns a
//!   [`crate::server::SketchServer`] into a primary; `SUBSCRIBE` flips
//!   a connection into a replication stream with ack-window
//!   backpressure);
//! * [`FollowerServer`] (+ [`FollowerConfig`]) — follower-side:
//!   subscribe / apply / ack, cursor resume across kills and
//!   reconnects ([`ReplicaCursor`]: the primary log's incarnation
//!   epoch + last applied seq, so a cursor from a restarted primary's
//!   previous log can never alias into the new numbering), full-sync
//!   fallback for stale or cross-epoch cursors, read-only serving of
//!   the replicated registry.
//!
//! # Semantics and limits
//!
//! Replication ships typed per-key deltas (wire-v3 `DELTA_BATCH`
//! entries):
//!
//! * **register diffs** — the exact dense registers that moved since
//!   the last capture (a handful of 5-byte entries instead of the full
//!   2^p-byte register file; the dirty tracker spills to a full resend
//!   past a density threshold), applied as per-register max-merges;
//! * **full sketches** — sparse-mode keys, merges, spilled diffs and
//!   re-created keys, applied through
//!   [`crate::registry::SketchRegistry::merge_sketch`];
//! * **tombstones** — evictions (explicit, TTL sweep, budget), applied
//!   as removals, so an evicting primary stays convergent with its
//!   followers instead of leaving them grow-only. A key evicted and
//!   re-created between two captures drains as tombstone *then* new
//!   sketch; batch entries apply in order, which is what stops a
//!   follower from max-merging the dead incarnation's registers into
//!   the new one.
//!
//! A `FULL_SYNC` *replaces* follower state (validated whole before the
//! swap, so a bad image halts with last-good state still serving):
//! when tombstone batches rotate out of log retention before a
//! disconnected follower resyncs, the stale-cursor full sync is what
//! removes the keys the primary dropped — merge-only application would
//! resurrect them forever. Legacy (pre-tombstone) subscribers
//! negotiate their delta wire in `SUBSCRIBE`; a v2 subscriber receives
//! full-sketch-only batches (diffs inflated, tombstones dropped —
//! grow-only, the semantics it was built for), and a follower that
//! cannot decode its primary's frames halts with a typed error instead
//! of reconnect-looping.
//!
//! The *global union* replicates through its own changed-register
//! dirty tracking: every capture that saw global registers rise seals
//! one `GLOBAL_DIFF` entry (the global sketch's raised registers, same
//! codec as a key diff), so words ingested into a key that is evicted
//! before the next capture still reach followers'
//! `GlobalEstimate` — per-key deltas die with the key, the global diff
//! does not. (Legacy v2 subscribers don't receive it; their global
//! stays derived from live-key merges, grow-only as before.) A
//! `FULL_SYNC` body is one in-band frame, so registries whose snapshot
//! image exceeds the frame cap ([`crate::server::MAX_PAYLOAD`]) must
//! bootstrap followers from a snapshot file instead.
//!
//! ```no_run
//! use std::sync::Arc;
//! use std::time::Duration;
//! use hll_fpga::registry::{RegistryConfig, SketchRegistry};
//! use hll_fpga::replica::{FollowerConfig, FollowerServer, ReplicationConfig};
//! use hll_fpga::server::{ServerConfig, SketchClient, SketchServer};
//!
//! // Primary: a normal server with replication turned on.
//! let primary_reg = SketchRegistry::shared(RegistryConfig::default()).unwrap();
//! let primary = SketchServer::start(
//!     "127.0.0.1:0",
//!     primary_reg.clone(),
//!     ServerConfig { replication: Some(ReplicationConfig::default()), ..Default::default() },
//! )
//! .unwrap();
//!
//! // Follower: replicates the primary, serves reads, rejects writes.
//! let follower_reg = SketchRegistry::shared(RegistryConfig::default()).unwrap();
//! let follower = FollowerServer::start(
//!     "127.0.0.1:0",
//!     primary.local_addr(),
//!     follower_reg,
//!     FollowerConfig::default(),
//! )
//! .unwrap();
//!
//! let mut producer = SketchClient::connect(primary.local_addr()).unwrap();
//! producer.insert_batch(42, &[1, 2, 3]).unwrap();
//! // ... after the stream drains, a client of `follower.local_addr()`
//! // answers the same estimates as the primary, bit-exactly.
//! ```

pub mod follower;
pub mod log;

pub use follower::{FollowerConfig, FollowerServer, FollowerStats};
pub use log::{
    LogRead, ReplicaCursor, ReplicationConfig, ReplicationLog, ReplicationLogStats,
    SealedBatch,
};
