//! The primary's replication log: dirty registry state sealed into
//! ordered, immutable delta batches that subscriber connections stream
//! to followers.
//!
//! A batch is one [`crate::registry::SketchRegistry::drain_dirty_deltas`]
//! drain — every key mutated since the previous capture, carried as a
//! typed [`SketchDelta`]: a sparse *register diff* when the exact dense
//! registers that moved were tracked (the common steady-state case — a
//! handful of 5-byte entries instead of the full 2^p-byte register
//! file, the same ship-registers-not-sketches instinct as the paper's
//! FPGA pipelines), a *full sketch* for sparse-mode keys, merges and
//! diff spills, and a *tombstone* when the key was evicted. Because
//! register applies are bucket-wise maxes (commutative, associative,
//! idempotent — the property the paper exploits to fold parallel
//! pipelines, Fig 3), diff/full entries are replay- and
//! reorder-tolerant; tombstones are ordered *within* the entry stream
//! (an evict-then-recreate drains as tombstone **then** new sketch), so
//! followers must apply a batch's entries in order.
//!
//! Batches are retained in a byte-bounded ring for cursor-based resume
//! after a follower disconnect; a cursor that has rotated out of
//! retention (or that predates this primary incarnation) falls back to
//! a full sync. Sealed batches are `Arc`-shared — N subscribers stream
//! one encode-source with zero per-subscriber copies of the entries.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::Duration;

use crate::obs::{Span, Stage};
use crate::registry::{SketchDelta, SketchRegistry};
use crate::server::protocol::{DELTA_ENTRY_OVERHEAD, MAX_PAYLOAD, MAX_WRITER_TRACES};

/// Upper bound on one sealed batch's entry payload. A capture that
/// drains more than this splits into several consecutive batches, so an
/// encoded `DELTA_BATCH` frame can never approach the protocol's
/// [`MAX_PAYLOAD`] cap — an oversized frame would be rejected by the
/// follower's header parser and wedge the stream in a reconnect loop.
const MAX_BATCH_BYTES: usize = (MAX_PAYLOAD as usize) / 4;

/// A follower's resumable replication position: the primary-log
/// incarnation (`epoch`) plus the last applied seq within it. Seqs are
/// only meaningful relative to the log that issued them — a restarted
/// primary starts a fresh log at seq 0 under a new epoch, and without
/// the epoch a saved cursor could silently alias into the new log's
/// numbering and skip its early batches. A cursor whose epoch does not
/// match the primary's always falls back to a full sync.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ReplicaCursor {
    /// The issuing log's incarnation id (0 = no position yet).
    pub epoch: u64,
    /// Last applied seq within that epoch.
    pub seq: u64,
}

/// Primary-side replication parameters (lives on
/// [`crate::server::ServerConfig::replication`]).
#[derive(Debug, Clone)]
pub struct ReplicationConfig {
    /// Cadence of the capture thread: how often dirty keys are drained
    /// into a sealed batch. Shorter = lower follower lag, more (and
    /// smaller) batches.
    pub capture_interval: Duration,
    /// Byte budget for retained sealed batches (entry payloads). Older
    /// batches rotate out once exceeded; a follower resuming from a
    /// rotated-out cursor gets a full sync instead of deltas.
    pub retain_bytes: usize,
    /// Max sealed batches a subscriber may have in flight unacked
    /// before the stream waits for `REPLICA_ACK` frames — backpressure
    /// against slow followers.
    pub ack_window: u64,
}

impl Default for ReplicationConfig {
    fn default() -> Self {
        Self {
            capture_interval: Duration::from_millis(10),
            retain_bytes: 64 << 20,
            ack_window: 64,
        }
    }
}

/// One immutable sealed batch: the dirty keys of one capture, each as a
/// typed delta. Entry order within a batch is significant (tombstone
/// before re-created sketch for the same key).
#[derive(Debug)]
pub struct SealedBatch {
    /// Position in the log (1-based, consecutive across sealed batches;
    /// a follower that has applied seq N resumes with cursor N).
    pub seq: u64,
    /// Registry logical clock when the batch was captured (diagnostic —
    /// ties a batch back to [`SketchRegistry::now`] ticks).
    pub clock: u64,
    /// `(key, delta)` per dirty key, in drain order.
    pub entries: Vec<(u64, SketchDelta)>,
    /// Encoded entry size (bodies + per-entry wire overhead), used for
    /// retention accounting and the batch split cap.
    pub bytes: usize,
    /// Wall-clock seal time (unix nanoseconds, [`crate::obs::unix_time_ns`]),
    /// shipped as a trailing `SEAL_TS` wire entry so followers can
    /// measure seal-to-apply replication latency across processes
    /// (monotonic clocks don't travel).
    pub sealed_unix_ns: u64,
    /// Trace IDs of traced writes whose mutations this capture sealed
    /// (the "last writers", at most [`MAX_WRITER_TRACES`], deposited via
    /// [`ReplicationLog::note_writer_trace`]). Shipped as a trailing
    /// `TRACE_IDS` wire entry on delta wire v4+, so a follower's apply
    /// span joins the writer's primary-side trace. Best-effort
    /// diagnostics: a seal racing an ingest may carry the ID one batch
    /// early, and untraced writes leave it empty.
    pub writer_traces: Vec<u64>,
}

/// Point-in-time log accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ReplicationLogStats {
    /// Batches sealed since start (including rotated-out ones).
    pub sealed_batches: u64,
    /// Entries (key frames) sealed since start.
    pub sealed_entries: u64,
    /// Of those, eviction tombstones.
    pub sealed_tombstones: u64,
    /// Of those, changed-register diffs.
    pub sealed_diff_entries: u64,
    /// Of those, full-sketch resends.
    pub sealed_full_entries: u64,
    /// Of those, global-union register diffs (at most one per capture).
    pub sealed_global_diffs: u64,
    /// Encoded entry bytes sealed since start (including rotated-out
    /// batches) — with `sealed_entries`, the bytes-per-replicated-key
    /// input of `benches/replication_lag.rs`.
    pub sealed_bytes: u64,
    /// Batches currently retained for cursor resume.
    pub retained_batches: usize,
    /// Entry-payload bytes currently retained.
    pub retained_bytes: usize,
    /// Seq of the newest sealed batch (0 = nothing sealed yet).
    pub latest_seq: u64,
    /// Seq of the oldest retained batch, if any.
    pub oldest_retained_seq: Option<u64>,
}

/// What [`ReplicationLog::read_after`] found for a subscriber cursor.
#[derive(Debug, Clone)]
pub enum LogRead {
    /// The next batch past the cursor, ready to ship.
    Batch(Arc<SealedBatch>),
    /// The cursor is at the log head; nothing to ship right now.
    CaughtUp,
    /// The cursor is unservable: it predates retention or claims a seq
    /// this log never sealed (a previous primary incarnation). The
    /// subscriber needs a full sync.
    Stale,
}

#[derive(Debug)]
struct LogInner {
    /// Retained batches, consecutive seqs `front.seq ..= back.seq`.
    batches: VecDeque<Arc<SealedBatch>>,
    /// Seq the next sealed batch will get (sealed so far: `1..next_seq`).
    next_seq: u64,
    retained_bytes: usize,
    sealed_batches: u64,
    sealed_entries: u64,
    sealed_tombstones: u64,
    sealed_diff_entries: u64,
    sealed_full_entries: u64,
    sealed_global_diffs: u64,
    sealed_bytes: u64,
}

/// The shared, internally locked replication log. The lock guards only
/// pointer-sized pushes/clones — entry payloads live in `Arc`ed sealed
/// batches, so subscriber fan-out never copies them.
#[derive(Debug)]
pub struct ReplicationLog {
    inner: Mutex<LogInner>,
    /// Serializes whole [`ReplicationLog::capture`] calls (drain
    /// through seal) against each other, so log order always equals
    /// drain order, without making subscribers' `inner` reads wait out
    /// a drain's shard walks and sketch serialization.
    capture_gate: Mutex<()>,
    /// This log incarnation's id, carried in `SUBSCRIBE`/`FULL_SYNC`
    /// frames so followers can tell a restarted primary (fresh seq
    /// numbering) from the one that issued their cursor.
    epoch: u64,
    /// `capture` calls currently between drain and seal. Lets a drain
    /// barrier (tests, benches, controlled shutdown) distinguish "log
    /// head is final" from "a concurrent capture is about to seal one
    /// more batch" — see [`ReplicationLog::captures_in_flight`].
    capturing: AtomicU64,
    /// Rotating deposit slots for traced writers
    /// ([`ReplicationLog::note_writer_trace`]): lock-free stores on the
    /// ingest path, drained (swapped to 0) by the next capture that
    /// seals entries. Past [`MAX_WRITER_TRACES`] concurrent depositors
    /// the oldest ID is overwritten — last writers win, by design.
    writer_traces: [AtomicU64; MAX_WRITER_TRACES],
    /// Next deposit slot (monotonic; modulo the slot count).
    writer_trace_cursor: AtomicU64,
}

impl Default for ReplicationLog {
    fn default() -> Self {
        Self::new()
    }
}

/// A practically unique nonzero epoch: wall-clock nanos mixed with the
/// process id and an in-process counter. Not cryptographic — it only
/// has to make accidental collision between two primary incarnations
/// vanishingly unlikely (a collision would merely skip a deserved full
/// sync, and only if the seq ranges also overlap).
fn unique_epoch() -> u64 {
    static COUNTER: AtomicU64 = AtomicU64::new(1);
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let pid = std::process::id() as u64;
    let raw = nanos ^ pid.rotate_left(32) ^ COUNTER.fetch_add(1, Ordering::Relaxed);
    if raw == 0 {
        1
    } else {
        raw
    }
}

impl ReplicationLog {
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(LogInner {
                batches: VecDeque::new(),
                next_seq: 1,
                retained_bytes: 0,
                sealed_batches: 0,
                sealed_entries: 0,
                sealed_tombstones: 0,
                sealed_diff_entries: 0,
                sealed_full_entries: 0,
                sealed_global_diffs: 0,
                sealed_bytes: 0,
            }),
            capture_gate: Mutex::new(()),
            epoch: unique_epoch(),
            capturing: AtomicU64::new(0),
            writer_traces: std::array::from_fn(|_| AtomicU64::new(0)),
            writer_trace_cursor: AtomicU64::new(0),
        }
    }

    /// Deposit a traced write's ID so the next sealed batch carries it
    /// to followers (see [`SealedBatch::writer_traces`]). Lock-free and
    /// wait-free: one relaxed `fetch_add` and one relaxed store into a
    /// rotating slot array — safe on the ingest hot path. Zero IDs
    /// (untraced) are the empty-slot sentinel and must not be deposited;
    /// callers gate on `trace_id != 0`.
    pub fn note_writer_trace(&self, trace_id: u64) {
        if trace_id == 0 {
            return;
        }
        let slot = self.writer_trace_cursor.fetch_add(1, Ordering::Relaxed) as usize
            % self.writer_traces.len();
        self.writer_traces[slot].store(trace_id, Ordering::Relaxed);
    }

    /// Drain the deposited writer-trace slots (swap to the empty
    /// sentinel), deduplicated. Called only by a capture that is about
    /// to seal entries, so deposits never vanish into an empty capture.
    fn take_writer_traces(&self) -> Vec<u64> {
        let mut out: Vec<u64> = self
            .writer_traces
            .iter()
            .map(|slot| slot.swap(0, Ordering::Relaxed))
            .filter(|&id| id != 0)
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// This log incarnation's id (nonzero; 0 on the wire means "no
    /// position yet").
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Number of [`ReplicationLog::capture`] calls currently running.
    /// When this is 0, the registry reports no dirty keys, and
    /// [`ReplicationLog::latest_seq`] is unchanged across the check,
    /// the log head is final — the deterministic drain barrier the
    /// replication tests and bench sit behind.
    pub fn captures_in_flight(&self) -> u64 {
        self.capturing.load(Ordering::SeqCst)
    }

    /// Poison-tolerant lock, mirroring the registry shards: the log
    /// holds immutable sealed batches that cannot be left torn.
    fn lock(&self) -> MutexGuard<'_, LogInner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Seq of the newest sealed batch (0 when nothing has been sealed).
    pub fn latest_seq(&self) -> u64 {
        self.lock().next_seq - 1
    }

    /// Drain `registry`'s dirty keys and seal them as the next batch —
    /// or several consecutive batches when the drain exceeds
    /// [`MAX_BATCH_BYTES`], so no single `DELTA_BATCH` frame can
    /// approach the protocol payload cap — rotating old batches past
    /// `retain_bytes`. Returns the last sealed seq, or `None` when
    /// nothing was dirty. Concurrent captures are safe: drain and seal
    /// happen under one hold of a dedicated capture gate, so racing
    /// capture calls serialize whole and log order always equals drain
    /// order. (With tombstones in the stream that is load-bearing, not
    /// a nicety — if a capturer could drain a key's tombstone, stall,
    /// and seal it *after* a second capturer sealed the re-created
    /// key's sketch, followers would apply resend-then-tombstone and
    /// delete a live key.) One capturer — the server's capture thread —
    /// is still the intended shape; tests call this directly to force a
    /// deterministic flush.
    pub fn capture(&self, registry: &SketchRegistry<u64>, retain_bytes: usize) -> Option<u64> {
        self.capturing.fetch_add(1, Ordering::SeqCst);
        let sealed = self.capture_inner(registry, retain_bytes);
        self.capturing.fetch_sub(1, Ordering::SeqCst);
        sealed
    }

    fn capture_inner(&self, registry: &SketchRegistry<u64>, retain_bytes: usize) -> Option<u64> {
        // The capture gate is held from before the drain until the seal
        // completes: racing capture calls serialize *whole*, so a drain
        // that saw a key's tombstone can never have its seal overtaken
        // by a later drain that saw the key re-created. Sealing itself
        // takes the inner lock only briefly — subscribers reading the
        // ring are never blocked behind a drain's shard walks and
        // sketch serialization.
        let _gate = self.capture_gate.lock().unwrap_or_else(PoisonError::into_inner);
        let mut entries = registry.drain_dirty_deltas();
        // The global union's own changed registers ride the same batch
        // (key 0, ignored on apply): per-key deltas die with an evicted
        // key, this entry does not — it is what carries
        // evicted-before-capture words into followers' global estimate.
        // Drained after the shards, so a racing ingest that already
        // marked its key dirty cannot leave global registers behind a
        // drained key delta.
        if let Some(bytes) = registry.drain_dirty_global() {
            entries.push((0, SketchDelta::GlobalDiff(bytes)));
        }
        if entries.is_empty() {
            return None;
        }
        // Drained only when entries will actually seal, so a deposit
        // racing an empty capture is not lost. Every chunk of a split
        // capture carries the same set — a follower joining mid-split
        // still sees the writers.
        let writer_traces = self.take_writer_traces();
        // The seal span joins the first writer's trace (0 = untraced
        // background capture), stitching primary-side seal time into
        // the same timeline as the write's decode/dispatch/ingest
        // spans. Ring-only: the capture thread has no histogram — the
        // aggregate seal cadence is already visible in the replication
        // gauges.
        let mut seal_span = Span::enter(
            Stage::Seal,
            writer_traces.first().copied().unwrap_or(0),
        );
        let clock = registry.now();
        let mut inner = self.lock();
        // Greedy chunking; chunks get consecutive seqs with nothing
        // interleaved, and drain order is preserved across chunk
        // boundaries, so a tombstone and its re-created sketch stay
        // ordered even when they land in consecutive batches.
        let mut last_seq = 0;
        let mut chunk: Vec<(u64, SketchDelta)> = Vec::new();
        let mut chunk_bytes = 0usize;
        for (key, delta) in entries {
            let entry_bytes = DELTA_ENTRY_OVERHEAD + delta.body_len();
            if !chunk.is_empty() && chunk_bytes + entry_bytes > MAX_BATCH_BYTES {
                last_seq = Self::seal_locked(
                    &mut inner,
                    std::mem::take(&mut chunk),
                    chunk_bytes,
                    clock,
                    retain_bytes,
                    writer_traces.clone(),
                );
                chunk_bytes = 0;
            }
            chunk.push((key, delta));
            chunk_bytes += entry_bytes;
        }
        if !chunk.is_empty() {
            last_seq = Self::seal_locked(
                &mut inner,
                chunk,
                chunk_bytes,
                clock,
                retain_bytes,
                writer_traces,
            );
        }
        seal_span.set_payload(last_seq);
        Some(last_seq)
    }

    /// Append one sealed batch and rotate past the retention budget —
    /// but never below one batch: the newest batch is what a
    /// just-caught-up follower's cursor points at.
    fn seal_locked(
        inner: &mut LogInner,
        entries: Vec<(u64, SketchDelta)>,
        bytes: usize,
        clock: u64,
        retain_bytes: usize,
        writer_traces: Vec<u64>,
    ) -> u64 {
        let n = entries.len() as u64;
        let seq = inner.next_seq;
        inner.next_seq += 1;
        for (_, delta) in &entries {
            match delta {
                SketchDelta::Tombstone => inner.sealed_tombstones += 1,
                SketchDelta::RegisterDiff(_) => inner.sealed_diff_entries += 1,
                SketchDelta::Full(_) => inner.sealed_full_entries += 1,
                SketchDelta::GlobalDiff(_) => inner.sealed_global_diffs += 1,
            }
        }
        inner.batches.push_back(Arc::new(SealedBatch {
            seq,
            clock,
            entries,
            bytes,
            sealed_unix_ns: crate::obs::unix_time_ns(),
            writer_traces,
        }));
        inner.retained_bytes += bytes;
        inner.sealed_batches += 1;
        inner.sealed_entries += n;
        inner.sealed_bytes += bytes as u64;
        while inner.retained_bytes > retain_bytes && inner.batches.len() > 1 {
            if let Some(dropped) = inner.batches.pop_front() {
                inner.retained_bytes -= dropped.bytes;
            }
        }
        seq
    }

    /// What a subscriber positioned at `cursor` (last applied seq)
    /// should receive next.
    pub fn read_after(&self, cursor: u64) -> LogRead {
        let inner = self.lock();
        let latest = inner.next_seq - 1;
        if cursor > latest {
            // A seq this log never sealed — the follower synced against
            // a previous primary incarnation.
            return LogRead::Stale;
        }
        if cursor == latest {
            return LogRead::CaughtUp;
        }
        match inner.batches.front() {
            Some(front) if front.seq <= cursor + 1 => {
                let idx = (cursor + 1 - front.seq) as usize;
                LogRead::Batch(inner.batches[idx].clone())
            }
            // cursor < latest but the batch after it rotated out.
            _ => LogRead::Stale,
        }
    }

    /// Deterministic drain barrier for tests, benches, examples and
    /// controlled shutdown: force-capture until the registry reports no
    /// dirty keys, no capture (this call's or the server's background
    /// thread's) is in flight, and the head stopped moving across the
    /// check — the returned head is then final, and a follower that has
    /// applied it holds everything. Batches are sealed with unbounded
    /// retention so a catching-up follower can still fetch them. Panics
    /// if `timeout` elapses first (this is a barrier for controlled
    /// environments, not a serving path).
    pub fn seal_all(&self, registry: &SketchRegistry<u64>, timeout: Duration) -> u64 {
        let deadline = std::time::Instant::now() + timeout;
        loop {
            self.capture(registry, usize::MAX);
            let latest = self.latest_seq();
            if registry.dirty_keys() == 0
                && registry.dirty_global_registers() == 0
                && self.captures_in_flight() == 0
                && self.latest_seq() == latest
            {
                return latest;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "replication never fully drained within {timeout:?}"
            );
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    /// How far a subscriber positioned at `cursor` (last applied seq)
    /// trails the log head, as `(entries, bytes)` over the retained
    /// batches past the cursor. Cursors that predate retention count
    /// everything retained (a lower bound); cursors past the head count
    /// zero. Feeds the primary's per-state replication-lag gauges.
    pub fn lag_after(&self, cursor: u64) -> (u64, u64) {
        let inner = self.lock();
        let mut entries = 0u64;
        let mut bytes = 0u64;
        for batch in inner.batches.iter().rev() {
            if batch.seq <= cursor {
                break;
            }
            entries += batch.entries.len() as u64;
            bytes += batch.bytes as u64;
        }
        (entries, bytes)
    }

    pub fn stats(&self) -> ReplicationLogStats {
        let inner = self.lock();
        ReplicationLogStats {
            sealed_batches: inner.sealed_batches,
            sealed_entries: inner.sealed_entries,
            sealed_tombstones: inner.sealed_tombstones,
            sealed_diff_entries: inner.sealed_diff_entries,
            sealed_full_entries: inner.sealed_full_entries,
            sealed_global_diffs: inner.sealed_global_diffs,
            sealed_bytes: inner.sealed_bytes,
            retained_batches: inner.batches.len(),
            retained_bytes: inner.retained_bytes,
            latest_seq: inner.next_seq - 1,
            oldest_retained_seq: inner.batches.front().map(|b| b.seq),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hll::HllSketch;
    use crate::registry::RegistryConfig;

    /// Global tracking off: these tests count sealed entries exactly,
    /// and a global-union diff entry per capture would shift every
    /// count (its sealing is covered by
    /// [`global_union_diffs_seal_alongside_key_entries`]).
    fn registry() -> SketchRegistry<u64> {
        let reg = SketchRegistry::new(RegistryConfig {
            shards: 8,
            track_global: false,
            ..RegistryConfig::default()
        })
        .unwrap();
        reg.enable_dirty_tracking();
        reg
    }

    #[test]
    fn capture_seals_consecutive_batches() {
        let reg = registry();
        let log = ReplicationLog::new();
        assert_eq!(log.latest_seq(), 0);
        assert!(log.capture(&reg, usize::MAX).is_none(), "nothing dirty yet");

        reg.ingest(1, &[1, 2, 3]);
        reg.ingest(2, &[4, 5]);
        assert_eq!(log.capture(&reg, usize::MAX), Some(1));
        reg.ingest(1, &[6]);
        assert_eq!(log.capture(&reg, usize::MAX), Some(2));
        assert!(log.capture(&reg, usize::MAX).is_none());

        let stats = log.stats();
        assert_eq!(stats.sealed_batches, 2);
        assert_eq!(stats.sealed_entries, 3); // keys 1+2, then key 1 again
        assert_eq!(stats.latest_seq, 2);
        assert_eq!(stats.oldest_retained_seq, Some(1));

        // Batch entries decode as the keys' sketches at capture time
        // (fresh sparse keys resend Full).
        match log.read_after(0) {
            LogRead::Batch(b) => {
                assert_eq!(b.seq, 1);
                assert_eq!(b.entries.len(), 2);
                for (_, delta) in &b.entries {
                    match delta {
                        SketchDelta::Full(bytes) => {
                            HllSketch::from_bytes(bytes).unwrap();
                        }
                        other => panic!("fresh key must seal Full, got {other:?}"),
                    }
                }
            }
            other => panic!("expected batch 1, got {other:?}"),
        }
        match log.read_after(1) {
            LogRead::Batch(b) => assert_eq!(b.seq, 2),
            other => panic!("expected batch 2, got {other:?}"),
        }
        assert!(matches!(log.read_after(2), LogRead::CaughtUp));
    }

    #[test]
    fn lag_after_counts_retained_entries_and_bytes_past_the_cursor() {
        let reg = registry();
        let log = ReplicationLog::new();
        reg.ingest(1, &[1, 2, 3]);
        reg.ingest(2, &[4, 5]);
        log.capture(&reg, usize::MAX); // seq 1: two entries
        reg.ingest(1, &[6]);
        log.capture(&reg, usize::MAX); // seq 2: one entry

        assert_eq!(log.lag_after(2), (0, 0), "at the head there is no lag");
        let (e1, b1) = log.lag_after(1);
        assert_eq!(e1, 1);
        assert!(b1 > 0);
        let (e0, b0) = log.lag_after(0);
        assert_eq!(e0, 3);
        assert!(b0 > b1, "a further-back cursor trails by strictly more bytes");
        // Past the head (a cursor from another incarnation): zero, not
        // a panic or an underflow.
        assert_eq!(log.lag_after(99), (0, 0));
        // Sealed batches carry a wall-clock seal stamp for the
        // follower's seal-to-apply latency measure.
        match log.read_after(0) {
            LogRead::Batch(b) => assert!(b.sealed_unix_ns > 0),
            other => panic!("expected batch 1, got {other:?}"),
        }
    }

    #[test]
    fn evictions_seal_ordered_tombstones() {
        let reg = registry();
        let log = ReplicationLog::new();
        reg.ingest(1, &[1, 2, 3]);
        reg.ingest(2, &[4]);
        assert_eq!(log.capture(&reg, usize::MAX), Some(1));

        // Evict key 1; evict and re-create key 2. One capture must seal
        // a tombstone for 1 and tombstone-then-full for 2, in order.
        reg.evict(&1);
        reg.evict(&2);
        reg.ingest(2, &[5, 6]);
        assert_eq!(log.capture(&reg, usize::MAX), Some(2));
        match log.read_after(1) {
            LogRead::Batch(b) => {
                let for_key = |key: u64| -> Vec<&SketchDelta> {
                    b.entries.iter().filter(|(k, _)| *k == key).map(|(_, d)| d).collect()
                };
                assert_eq!(for_key(1), vec![&SketchDelta::Tombstone]);
                let two = for_key(2);
                assert_eq!(two.len(), 2);
                assert_eq!(two[0], &SketchDelta::Tombstone, "tombstone must precede resend");
                assert!(matches!(two[1], SketchDelta::Full(_)));
            }
            other => panic!("expected batch 2, got {other:?}"),
        }
        let stats = log.stats();
        assert_eq!(stats.sealed_tombstones, 2);
        assert_eq!(stats.sealed_full_entries, 3); // keys 1+2 fresh, key 2 reborn
        assert!(stats.sealed_bytes > 0);
    }

    #[test]
    fn oversized_drains_split_into_capped_batches() {
        // 300 paper-config keys serialize to ~300 × 64 KiB ≈ 19.7 MB of
        // entry payload — past MAX_BATCH_BYTES (16 MiB), so one capture
        // must seal exactly two consecutive batches, each under the cap.
        let reg = registry();
        for key in 0u64..300 {
            reg.ingest(key, &[key as u32]);
        }
        let log = ReplicationLog::new();
        let last = log.capture(&reg, usize::MAX).unwrap();
        assert_eq!(last, 2, "drain must split into two sealed batches");
        let stats = log.stats();
        assert_eq!(stats.sealed_batches, 2);
        assert_eq!(stats.sealed_entries, 300);
        let mut cursor = 0;
        while let LogRead::Batch(batch) = log.read_after(cursor) {
            assert!(batch.bytes <= MAX_BATCH_BYTES, "batch {} too large", batch.seq);
            cursor = batch.seq;
        }
        assert_eq!(cursor, last);
    }

    #[test]
    fn global_union_diffs_seal_alongside_key_entries() {
        use crate::hll::decode_register_diff;

        // A registry *with* a global union: every capture that drained
        // raised global registers carries one GlobalDiff entry, and an
        // insert→evict-before-capture key still reaches the global
        // stream even though its own delta is just a tombstone.
        let reg = SketchRegistry::new(RegistryConfig {
            shards: 8,
            ..RegistryConfig::default()
        })
        .unwrap();
        reg.enable_dirty_tracking();
        let log = ReplicationLog::new();

        reg.ingest(1, &[10, 20, 30]);
        reg.evict(&1);
        assert!(reg.dirty_global_registers() > 0, "ingest must dirty the global union");
        assert_eq!(log.capture(&reg, usize::MAX), Some(1));
        assert_eq!(reg.dirty_global_registers(), 0, "capture must drain the global dirt");

        let global = reg.global_sketch().unwrap();
        match log.read_after(0) {
            LogRead::Batch(b) => {
                let tombs: Vec<u64> = b
                    .entries
                    .iter()
                    .filter(|(_, d)| matches!(d, SketchDelta::Tombstone))
                    .map(|(k, _)| *k)
                    .collect();
                assert_eq!(tombs, vec![1], "the dead key ships a tombstone");
                let diffs: Vec<&Vec<u8>> = b
                    .entries
                    .iter()
                    .filter_map(|(_, d)| match d {
                        SketchDelta::GlobalDiff(bytes) => Some(bytes),
                        _ => None,
                    })
                    .collect();
                assert_eq!(diffs.len(), 1, "exactly one global diff per capture");
                // Applying the diff to an empty sketch reproduces the
                // primary's global registers — the words survived the
                // eviction.
                let (cfg, entries) = decode_register_diff(diffs[0]).unwrap();
                assert_eq!(cfg, *global.config());
                let mut rebuilt = crate::hll::HllSketch::new(cfg);
                rebuilt.apply_register_diff(&entries);
                assert_eq!(rebuilt, global);
            }
            other => panic!("expected batch 1, got {other:?}"),
        }
        assert_eq!(log.stats().sealed_global_diffs, 1);

        // Nothing new: no empty global entry is sealed.
        assert!(log.capture(&reg, usize::MAX).is_none());
    }

    #[test]
    fn writer_traces_ride_the_next_seal_and_are_drained() {
        let reg = registry();
        let log = ReplicationLog::new();

        // Deposits before an empty capture survive it.
        log.note_writer_trace(0xAA);
        assert!(log.capture(&reg, usize::MAX).is_none(), "nothing dirty");

        log.note_writer_trace(0xBB);
        log.note_writer_trace(0); // untraced sentinel: never deposited
        reg.ingest(1, &[1, 2, 3]);
        assert_eq!(log.capture(&reg, usize::MAX), Some(1));
        match log.read_after(0) {
            LogRead::Batch(b) => assert_eq!(b.writer_traces, vec![0xAA, 0xBB]),
            other => panic!("expected batch 1, got {other:?}"),
        }

        // Drained: the next sealed batch starts clean.
        reg.ingest(2, &[4]);
        assert_eq!(log.capture(&reg, usize::MAX), Some(2));
        match log.read_after(1) {
            LogRead::Batch(b) => assert!(b.writer_traces.is_empty(), "deposits must drain"),
            other => panic!("expected batch 2, got {other:?}"),
        }

        // Bounded: past the slot count, old deposits are overwritten
        // (last writers win) and duplicates collapse.
        for i in 0..(MAX_WRITER_TRACES as u64 * 3) {
            log.note_writer_trace(1000 + i % (MAX_WRITER_TRACES as u64 + 4));
        }
        reg.ingest(3, &[5]);
        assert_eq!(log.capture(&reg, usize::MAX), Some(3));
        match log.read_after(2) {
            LogRead::Batch(b) => {
                assert!(!b.writer_traces.is_empty());
                assert!(b.writer_traces.len() <= MAX_WRITER_TRACES);
                let mut deduped = b.writer_traces.clone();
                deduped.dedup();
                assert_eq!(deduped, b.writer_traces, "IDs must be deduplicated");
            }
            other => panic!("expected batch 3, got {other:?}"),
        }
    }

    #[test]
    fn epochs_are_nonzero_and_distinct_per_log() {
        let a = ReplicationLog::new();
        let b = ReplicationLog::new();
        assert_ne!(a.epoch(), 0);
        assert_ne!(b.epoch(), 0);
        assert_ne!(a.epoch(), b.epoch(), "two incarnations must not share an epoch");
    }

    #[test]
    fn rotation_makes_old_cursors_stale_but_keeps_one_batch() {
        let reg = registry();
        let log = ReplicationLog::new();
        // retain_bytes = 1 rotates everything but the newest batch.
        for i in 0u32..5 {
            reg.ingest(i as u64, &[i]);
            assert_eq!(log.capture(&reg, 1), Some(i as u64 + 1));
        }
        let stats = log.stats();
        assert_eq!(stats.latest_seq, 5);
        assert_eq!(stats.retained_batches, 1);
        assert_eq!(stats.oldest_retained_seq, Some(5));

        // Cursor 4 still resumes (batch 5 is retained); older cursors
        // are stale; a future cursor (other primary incarnation) too.
        assert!(matches!(log.read_after(4), LogRead::Batch(_)));
        assert!(matches!(log.read_after(5), LogRead::CaughtUp));
        for stale in [0u64, 1, 2, 3] {
            assert!(matches!(log.read_after(stale), LogRead::Stale), "cursor {stale}");
        }
        assert!(matches!(log.read_after(99), LogRead::Stale));
    }
}
