//! The primary's replication log: dirty registry state sealed into
//! ordered, immutable delta batches that subscriber connections stream
//! to followers.
//!
//! A batch is one [`crate::registry::SketchRegistry::drain_dirty_sketches`]
//! drain — every key mutated since the previous capture, each carried
//! as its *current full* sketch in wire format v2. Because sketch
//! merges are bucket-wise maxes (commutative, associative, idempotent —
//! the same property the paper's FPGA exploits to fold parallel
//! pipelines, Fig 3), shipping full per-key state makes the log trivial
//! to resume: replaying a batch, skipping ahead, or applying batches
//! around a full sync all converge to the same registers.
//!
//! Batches are retained in a byte-bounded ring for cursor-based resume
//! after a follower disconnect; a cursor that has rotated out of
//! retention (or that predates this primary incarnation) falls back to
//! a full sync. Sealed batches are `Arc`-shared — N subscribers stream
//! one encode-source with zero per-subscriber copies of the entries.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::Duration;

use crate::registry::SketchRegistry;
use crate::server::protocol::MAX_PAYLOAD;

/// Upper bound on one sealed batch's entry payload. A capture that
/// drains more than this splits into several consecutive batches, so an
/// encoded `DELTA_BATCH` frame can never approach the protocol's
/// [`MAX_PAYLOAD`] cap — an oversized frame would be rejected by the
/// follower's header parser and wedge the stream in a reconnect loop.
const MAX_BATCH_BYTES: usize = (MAX_PAYLOAD as usize) / 4;

/// A follower's resumable replication position: the primary-log
/// incarnation (`epoch`) plus the last applied seq within it. Seqs are
/// only meaningful relative to the log that issued them — a restarted
/// primary starts a fresh log at seq 0 under a new epoch, and without
/// the epoch a saved cursor could silently alias into the new log's
/// numbering and skip its early batches. A cursor whose epoch does not
/// match the primary's always falls back to a full sync.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ReplicaCursor {
    /// The issuing log's incarnation id (0 = no position yet).
    pub epoch: u64,
    /// Last applied seq within that epoch.
    pub seq: u64,
}

/// Primary-side replication parameters (lives on
/// [`crate::server::ServerConfig::replication`]).
#[derive(Debug, Clone)]
pub struct ReplicationConfig {
    /// Cadence of the capture thread: how often dirty keys are drained
    /// into a sealed batch. Shorter = lower follower lag, more (and
    /// smaller) batches.
    pub capture_interval: Duration,
    /// Byte budget for retained sealed batches (entry payloads). Older
    /// batches rotate out once exceeded; a follower resuming from a
    /// rotated-out cursor gets a full sync instead of deltas.
    pub retain_bytes: usize,
    /// Max sealed batches a subscriber may have in flight unacked
    /// before the stream waits for `REPLICA_ACK` frames — backpressure
    /// against slow followers.
    pub ack_window: u64,
}

impl Default for ReplicationConfig {
    fn default() -> Self {
        Self {
            capture_interval: Duration::from_millis(10),
            retain_bytes: 64 << 20,
            ack_window: 64,
        }
    }
}

/// One immutable sealed batch: the dirty keys of one capture, each with
/// its full sketch serialized in wire format v2.
#[derive(Debug)]
pub struct SealedBatch {
    /// Position in the log (1-based, consecutive across sealed batches;
    /// a follower that has applied seq N resumes with cursor N).
    pub seq: u64,
    /// Registry logical clock when the batch was captured (diagnostic —
    /// ties a batch back to [`SketchRegistry::now`] ticks).
    pub clock: u64,
    /// `(key, sketch wire-v2 bytes)` per dirty key.
    pub entries: Vec<(u64, Vec<u8>)>,
    /// Payload size used for retention accounting.
    pub bytes: usize,
}

/// Point-in-time log accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ReplicationLogStats {
    /// Batches sealed since start (including rotated-out ones).
    pub sealed_batches: u64,
    /// Entries (key frames) sealed since start.
    pub sealed_entries: u64,
    /// Batches currently retained for cursor resume.
    pub retained_batches: usize,
    /// Entry-payload bytes currently retained.
    pub retained_bytes: usize,
    /// Seq of the newest sealed batch (0 = nothing sealed yet).
    pub latest_seq: u64,
    /// Seq of the oldest retained batch, if any.
    pub oldest_retained_seq: Option<u64>,
}

/// What [`ReplicationLog::read_after`] found for a subscriber cursor.
#[derive(Debug, Clone)]
pub enum LogRead {
    /// The next batch past the cursor, ready to ship.
    Batch(Arc<SealedBatch>),
    /// The cursor is at the log head; nothing to ship right now.
    CaughtUp,
    /// The cursor is unservable: it predates retention or claims a seq
    /// this log never sealed (a previous primary incarnation). The
    /// subscriber needs a full sync.
    Stale,
}

#[derive(Debug)]
struct LogInner {
    /// Retained batches, consecutive seqs `front.seq ..= back.seq`.
    batches: VecDeque<Arc<SealedBatch>>,
    /// Seq the next sealed batch will get (sealed so far: `1..next_seq`).
    next_seq: u64,
    retained_bytes: usize,
    sealed_batches: u64,
    sealed_entries: u64,
}

/// The shared, internally locked replication log. The lock guards only
/// pointer-sized pushes/clones — entry payloads live in `Arc`ed sealed
/// batches, so subscriber fan-out never copies them.
#[derive(Debug)]
pub struct ReplicationLog {
    inner: Mutex<LogInner>,
    /// This log incarnation's id, carried in `SUBSCRIBE`/`FULL_SYNC`
    /// frames so followers can tell a restarted primary (fresh seq
    /// numbering) from the one that issued their cursor.
    epoch: u64,
    /// `capture` calls currently between drain and seal. Lets a drain
    /// barrier (tests, benches, controlled shutdown) distinguish "log
    /// head is final" from "a concurrent capture is about to seal one
    /// more batch" — see [`ReplicationLog::captures_in_flight`].
    capturing: AtomicU64,
}

impl Default for ReplicationLog {
    fn default() -> Self {
        Self::new()
    }
}

/// A practically unique nonzero epoch: wall-clock nanos mixed with the
/// process id and an in-process counter. Not cryptographic — it only
/// has to make accidental collision between two primary incarnations
/// vanishingly unlikely (a collision would merely skip a deserved full
/// sync, and only if the seq ranges also overlap).
fn unique_epoch() -> u64 {
    static COUNTER: AtomicU64 = AtomicU64::new(1);
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let pid = std::process::id() as u64;
    let raw = nanos ^ pid.rotate_left(32) ^ COUNTER.fetch_add(1, Ordering::Relaxed);
    if raw == 0 {
        1
    } else {
        raw
    }
}

impl ReplicationLog {
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(LogInner {
                batches: VecDeque::new(),
                next_seq: 1,
                retained_bytes: 0,
                sealed_batches: 0,
                sealed_entries: 0,
            }),
            epoch: unique_epoch(),
            capturing: AtomicU64::new(0),
        }
    }

    /// This log incarnation's id (nonzero; 0 on the wire means "no
    /// position yet").
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Number of [`ReplicationLog::capture`] calls currently running.
    /// When this is 0, the registry reports no dirty keys, and
    /// [`ReplicationLog::latest_seq`] is unchanged across the check,
    /// the log head is final — the deterministic drain barrier the
    /// replication tests and bench sit behind.
    pub fn captures_in_flight(&self) -> u64 {
        self.capturing.load(Ordering::SeqCst)
    }

    /// Poison-tolerant lock, mirroring the registry shards: the log
    /// holds immutable sealed batches that cannot be left torn.
    fn lock(&self) -> MutexGuard<'_, LogInner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Seq of the newest sealed batch (0 when nothing has been sealed).
    pub fn latest_seq(&self) -> u64 {
        self.lock().next_seq - 1
    }

    /// Drain `registry`'s dirty keys and seal them as the next batch —
    /// or several consecutive batches when the drain exceeds
    /// [`MAX_BATCH_BYTES`], so no single `DELTA_BATCH` frame can
    /// approach the protocol payload cap — rotating old batches past
    /// `retain_bytes`. Returns the last sealed seq, or `None` when
    /// nothing was dirty. Concurrent captures are safe (disjoint
    /// drains; duplicates are idempotent max-merges on the follower),
    /// but one capturer — the server's capture thread — is the intended
    /// shape; tests call this directly to force a deterministic flush.
    pub fn capture(&self, registry: &SketchRegistry<u64>, retain_bytes: usize) -> Option<u64> {
        self.capturing.fetch_add(1, Ordering::SeqCst);
        let sealed = self.capture_inner(registry, retain_bytes);
        self.capturing.fetch_sub(1, Ordering::SeqCst);
        sealed
    }

    fn capture_inner(&self, registry: &SketchRegistry<u64>, retain_bytes: usize) -> Option<u64> {
        let entries = registry.drain_dirty_sketches();
        if entries.is_empty() {
            return None;
        }
        let clock = registry.now();
        // Greedy chunking; the lock is held across the whole drain so
        // its chunks get consecutive seqs with nothing interleaved.
        let mut inner = self.lock();
        let mut last_seq = 0;
        let mut chunk: Vec<(u64, Vec<u8>)> = Vec::new();
        let mut chunk_bytes = 0usize;
        for (key, bytes) in entries {
            let entry_bytes = 12 + bytes.len();
            if !chunk.is_empty() && chunk_bytes + entry_bytes > MAX_BATCH_BYTES {
                last_seq = Self::seal_locked(
                    &mut inner,
                    std::mem::take(&mut chunk),
                    chunk_bytes,
                    clock,
                    retain_bytes,
                );
                chunk_bytes = 0;
            }
            chunk.push((key, bytes));
            chunk_bytes += entry_bytes;
        }
        if !chunk.is_empty() {
            last_seq = Self::seal_locked(&mut inner, chunk, chunk_bytes, clock, retain_bytes);
        }
        Some(last_seq)
    }

    /// Append one sealed batch and rotate past the retention budget —
    /// but never below one batch: the newest batch is what a
    /// just-caught-up follower's cursor points at.
    fn seal_locked(
        inner: &mut LogInner,
        entries: Vec<(u64, Vec<u8>)>,
        bytes: usize,
        clock: u64,
        retain_bytes: usize,
    ) -> u64 {
        let n = entries.len() as u64;
        let seq = inner.next_seq;
        inner.next_seq += 1;
        inner.batches.push_back(Arc::new(SealedBatch { seq, clock, entries, bytes }));
        inner.retained_bytes += bytes;
        inner.sealed_batches += 1;
        inner.sealed_entries += n;
        while inner.retained_bytes > retain_bytes && inner.batches.len() > 1 {
            if let Some(dropped) = inner.batches.pop_front() {
                inner.retained_bytes -= dropped.bytes;
            }
        }
        seq
    }

    /// What a subscriber positioned at `cursor` (last applied seq)
    /// should receive next.
    pub fn read_after(&self, cursor: u64) -> LogRead {
        let inner = self.lock();
        let latest = inner.next_seq - 1;
        if cursor > latest {
            // A seq this log never sealed — the follower synced against
            // a previous primary incarnation.
            return LogRead::Stale;
        }
        if cursor == latest {
            return LogRead::CaughtUp;
        }
        match inner.batches.front() {
            Some(front) if front.seq <= cursor + 1 => {
                let idx = (cursor + 1 - front.seq) as usize;
                LogRead::Batch(inner.batches[idx].clone())
            }
            // cursor < latest but the batch after it rotated out.
            _ => LogRead::Stale,
        }
    }

    pub fn stats(&self) -> ReplicationLogStats {
        let inner = self.lock();
        ReplicationLogStats {
            sealed_batches: inner.sealed_batches,
            sealed_entries: inner.sealed_entries,
            retained_batches: inner.batches.len(),
            retained_bytes: inner.retained_bytes,
            latest_seq: inner.next_seq - 1,
            oldest_retained_seq: inner.batches.front().map(|b| b.seq),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hll::HllSketch;
    use crate::registry::RegistryConfig;

    fn registry() -> SketchRegistry<u64> {
        let reg = SketchRegistry::new(RegistryConfig {
            shards: 8,
            ..RegistryConfig::default()
        })
        .unwrap();
        reg.enable_dirty_tracking();
        reg
    }

    #[test]
    fn capture_seals_consecutive_batches() {
        let reg = registry();
        let log = ReplicationLog::new();
        assert_eq!(log.latest_seq(), 0);
        assert!(log.capture(&reg, usize::MAX).is_none(), "nothing dirty yet");

        reg.ingest(1, &[1, 2, 3]);
        reg.ingest(2, &[4, 5]);
        assert_eq!(log.capture(&reg, usize::MAX), Some(1));
        reg.ingest(1, &[6]);
        assert_eq!(log.capture(&reg, usize::MAX), Some(2));
        assert!(log.capture(&reg, usize::MAX).is_none());

        let stats = log.stats();
        assert_eq!(stats.sealed_batches, 2);
        assert_eq!(stats.sealed_entries, 3); // keys 1+2, then key 1 again
        assert_eq!(stats.latest_seq, 2);
        assert_eq!(stats.oldest_retained_seq, Some(1));

        // Batch entries decode as the keys' sketches at capture time.
        match log.read_after(0) {
            LogRead::Batch(b) => {
                assert_eq!(b.seq, 1);
                assert_eq!(b.entries.len(), 2);
                for (_, bytes) in &b.entries {
                    HllSketch::from_bytes(bytes).unwrap();
                }
            }
            other => panic!("expected batch 1, got {other:?}"),
        }
        match log.read_after(1) {
            LogRead::Batch(b) => assert_eq!(b.seq, 2),
            other => panic!("expected batch 2, got {other:?}"),
        }
        assert!(matches!(log.read_after(2), LogRead::CaughtUp));
    }

    #[test]
    fn oversized_drains_split_into_capped_batches() {
        // 300 paper-config keys serialize to ~300 × 64 KiB ≈ 19.7 MB of
        // entry payload — past MAX_BATCH_BYTES (16 MiB), so one capture
        // must seal exactly two consecutive batches, each under the cap.
        let reg = registry();
        for key in 0u64..300 {
            reg.ingest(key, &[key as u32]);
        }
        let log = ReplicationLog::new();
        let last = log.capture(&reg, usize::MAX).unwrap();
        assert_eq!(last, 2, "drain must split into two sealed batches");
        let stats = log.stats();
        assert_eq!(stats.sealed_batches, 2);
        assert_eq!(stats.sealed_entries, 300);
        let mut cursor = 0;
        while let LogRead::Batch(batch) = log.read_after(cursor) {
            assert!(batch.bytes <= MAX_BATCH_BYTES, "batch {} too large", batch.seq);
            cursor = batch.seq;
        }
        assert_eq!(cursor, last);
    }

    #[test]
    fn epochs_are_nonzero_and_distinct_per_log() {
        let a = ReplicationLog::new();
        let b = ReplicationLog::new();
        assert_ne!(a.epoch(), 0);
        assert_ne!(b.epoch(), 0);
        assert_ne!(a.epoch(), b.epoch(), "two incarnations must not share an epoch");
    }

    #[test]
    fn rotation_makes_old_cursors_stale_but_keeps_one_batch() {
        let reg = registry();
        let log = ReplicationLog::new();
        // retain_bytes = 1 rotates everything but the newest batch.
        for i in 0u32..5 {
            reg.ingest(i as u64, &[i]);
            assert_eq!(log.capture(&reg, 1), Some(i as u64 + 1));
        }
        let stats = log.stats();
        assert_eq!(stats.latest_seq, 5);
        assert_eq!(stats.retained_batches, 1);
        assert_eq!(stats.oldest_retained_seq, Some(5));

        // Cursor 4 still resumes (batch 5 is retained); older cursors
        // are stale; a future cursor (other primary incarnation) too.
        assert!(matches!(log.read_after(4), LogRead::Batch(_)));
        assert!(matches!(log.read_after(5), LogRead::CaughtUp));
        for stale in [0u64, 1, 2, 3] {
            assert!(matches!(log.read_after(stale), LogRead::Stale), "cursor {stale}");
        }
        assert!(matches!(log.read_after(99), LogRead::Stale));
    }
}
