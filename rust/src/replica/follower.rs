//! The follower: a read-only serving node kept convergent with a
//! primary over the replication stream.
//!
//! [`FollowerServer::start`] wraps two pieces sharing one registry:
//!
//! * a [`SketchServer`] in read-only mode — `Estimate`,
//!   `GlobalEstimate`, `Stats` and `Ping` serve normally, every
//!   mutating RPC answers a typed
//!   [`crate::server::ErrorCode::ReadOnly`] frame;
//! * a replication thread that subscribes to the primary, applies
//!   `FULL_SYNC` / `DELTA_BATCH` frames in entry order — full sketches
//!   through [`SketchRegistry::merge_sketch`] and register diffs
//!   through [`SketchRegistry::apply_register_diff`] (max-merges — the
//!   paper's Fig-3 fold — so replays and duplicates converge to the
//!   primary's registers bit-exactly) and tombstones as evictions, so
//!   TTL/budget sweeps on the primary propagate instead of leaving the
//!   follower grow-only — acks each applied position, and reconnects
//!   with its cursor after a disconnect.
//!
//! A follower that is killed and restarted resumes from its last
//! applied cursor ([`FollowerServer::shutdown`] returns it;
//! [`FollowerServer::start_at_cursor`] takes it): if the primary still
//! retains the intervening batches, only those ship; otherwise the
//! primary falls back to a full sync. Sketch config mismatches
//! (precision or hash seed) surface as typed errors and **halt**
//! replication — the follower keeps serving its last-good state rather
//! than retry-looping into the same rejection
//! ([`FollowerStats::halted`] + [`FollowerStats::last_error`] expose
//! the condition).

use std::io::{self, Read};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::ReplicaCursor;
use crate::hll::{decode_register_diff, HllSketch, SketchError};
use crate::obs::recorder;
use crate::obs::{LatencyHistogram, MetricsRegistry, Span, Stage};
use crate::registry::{SketchDelta, SketchRegistry};
use crate::server::protocol::{
    ErrorCode, FrameDecoder, ProtocolError, Request, Response, DELTA_WIRE_V4,
};
use crate::server::server::write_full;
use crate::server::snapshot;
use crate::server::{ServerConfig, SketchServer};

/// Follower-side replication parameters.
#[derive(Debug, Clone)]
pub struct FollowerConfig {
    /// Pause between reconnect attempts after a connect failure or a
    /// dropped subscription.
    pub reconnect_backoff: Duration,
    /// Socket read timeout — the granularity at which the apply loop
    /// notices shutdown and reconnects.
    pub read_timeout: Duration,
}

impl Default for FollowerConfig {
    fn default() -> Self {
        Self {
            reconnect_backoff: Duration::from_millis(50),
            read_timeout: Duration::from_millis(20),
        }
    }
}

/// Point-in-time follower counters.
#[derive(Debug, Clone, PartialEq)]
pub struct FollowerStats {
    /// Highest replication seq applied (the resume cursor).
    pub cursor: u64,
    /// Delta batches applied since start.
    pub batches_applied: u64,
    /// Per-key frames applied since start (deltas only).
    pub entries_applied: u64,
    /// Of those, eviction tombstones (keys removed to track the
    /// primary's TTL/budget sweeps).
    pub tombstones_applied: u64,
    /// Of those, changed-register diffs (wire-v3 compaction path).
    pub diff_entries_applied: u64,
    /// Of those, global-union register diffs — words whose key was
    /// evicted on the primary before the capture tick, folded into this
    /// follower's `GlobalEstimate`.
    pub global_diffs_applied: u64,
    /// Full syncs applied since start (bootstrap + stale-cursor falls).
    pub full_syncs: u64,
    /// Reconnect attempts after the initial connect.
    pub reconnects: u64,
    /// Replication has halted on a non-recoverable typed error (config
    /// mismatch, unsupported primary); reads still serve.
    pub halted: bool,
    /// The most recent replication error, if any.
    pub last_error: Option<String>,
}

#[derive(Debug, Default)]
struct FollowerShared {
    /// Primary log incarnation the cursor belongs to (0 = none yet).
    epoch: AtomicU64,
    cursor: AtomicU64,
    batches_applied: AtomicU64,
    entries_applied: AtomicU64,
    tombstones_applied: AtomicU64,
    diff_entries_applied: AtomicU64,
    global_diffs_applied: AtomicU64,
    full_syncs: AtomicU64,
    reconnects: AtomicU64,
    halted: AtomicBool,
    last_error: Mutex<Option<String>>,
    /// Seal-to-apply replication latency: wall-clock ns from the
    /// primary sealing a batch (its `SEAL_TS` stamp on the v3 wire) to
    /// this follower applying it. Registered into the wrapped server's
    /// metrics as `replica_seal_to_apply_ns`; crosses processes, so the
    /// two clocks must be roughly synchronized for absolute values
    /// (trends survive skew).
    seal_to_apply_ns: Arc<LatencyHistogram>,
    /// Per-batch apply duration, fed by the `FollowerApply` span into
    /// the wrapped server's `stage_latency_ns{stage="follower_apply"}`
    /// series (same cell the server's [`crate::obs::StageTimers`]
    /// pre-declared).
    apply_ns: Arc<LatencyHistogram>,
}

impl FollowerShared {
    fn record_error(&self, e: impl std::fmt::Display) {
        *self.last_error.lock().unwrap_or_else(PoisonError::into_inner) = Some(e.to_string());
    }

    /// Terminal replication stop: record the reason, raise the halt
    /// flag, and freeze the flight recorder's ring into the black box —
    /// a halt is exactly the anomaly the recorder exists for, and the
    /// events leading up to it (the batch's apply span, the primary's
    /// spans when in-process) would otherwise be overwritten.
    fn halt(&self, why: String) {
        recorder::note_anomaly(&format!("follower halt: {why}"));
        self.record_error(why);
        self.halted.store(true, Ordering::SeqCst);
    }
}

/// A running follower: read-only TCP front-end plus the replication
/// apply thread. Dropping it performs a full graceful shutdown.
pub struct FollowerServer {
    server: SketchServer,
    stop: Arc<AtomicBool>,
    shared: Arc<FollowerShared>,
    join: Option<JoinHandle<()>>,
}

impl FollowerServer {
    /// Bootstrap a fresh follower: bind `listen` for read-only serving
    /// and subscribe to `primary` from cursor 0 (the primary answers
    /// with a full sync, then streams deltas).
    pub fn start(
        listen: impl ToSocketAddrs,
        primary: SocketAddr,
        registry: Arc<SketchRegistry<u64>>,
        cfg: FollowerConfig,
    ) -> io::Result<Self> {
        Self::start_at_cursor(listen, primary, registry, cfg, ReplicaCursor::default())
    }

    /// Resume a follower that already holds state up to `cursor` (the
    /// position a previous instance's [`FollowerServer::shutdown`]
    /// returned, against the same registry). The primary ships only the
    /// batches past the cursor if its log incarnation still matches and
    /// it retains them, falling back to a full sync otherwise.
    pub fn start_at_cursor(
        listen: impl ToSocketAddrs,
        primary: SocketAddr,
        registry: Arc<SketchRegistry<u64>>,
        cfg: FollowerConfig,
        cursor: ReplicaCursor,
    ) -> io::Result<Self> {
        let server = SketchServer::start(
            listen,
            registry.clone(),
            ServerConfig { read_only: true, ..ServerConfig::default() },
        )?;
        let stop = Arc::new(AtomicBool::new(false));
        let shared = Arc::new(FollowerShared {
            epoch: AtomicU64::new(cursor.epoch),
            cursor: AtomicU64::new(cursor.seq),
            seal_to_apply_ns: server.metrics().histogram("replica_seal_to_apply_ns", None),
            apply_ns: server.metrics().histogram(
                "stage_latency_ns",
                Some(("stage", Stage::FollowerApply.name().to_string())),
            ),
            ..FollowerShared::default()
        });
        register_replica_gauges(server.metrics(), &shared);
        let thread_stop = stop.clone();
        let thread_shared = shared.clone();
        let join = std::thread::Builder::new()
            .name("sketch-follower-replication".into())
            .spawn(move || {
                replication_loop(primary, registry, cfg, thread_stop, thread_shared)
            })?;
        Ok(Self { server, stop, shared, join: Some(join) })
    }

    /// The read-only serving address.
    pub fn local_addr(&self) -> SocketAddr {
        self.server.local_addr()
    }

    /// The registry replication applies into (shared with the serving
    /// front-end).
    pub fn registry(&self) -> &Arc<SketchRegistry<u64>> {
        self.server.registry()
    }

    /// The wrapped read-only server (for its serving stats).
    pub fn server(&self) -> &SketchServer {
        &self.server
    }

    /// The wrapped server's metrics registry — carries the `replica_*`
    /// series (cursor, applied counts, seal-to-apply latency) alongside
    /// the serving instruments, so one `MetricsDump` against the
    /// follower's port reads the whole node.
    pub fn metrics(&self) -> &Arc<MetricsRegistry> {
        self.server.metrics()
    }

    /// Highest replication seq applied so far (within the current
    /// primary epoch — compare against the primary log's
    /// `latest_seq` for caught-up checks).
    pub fn cursor(&self) -> u64 {
        self.shared.cursor.load(Ordering::SeqCst)
    }

    /// The full resumable position (epoch + seq) a successor would pass
    /// to [`FollowerServer::start_at_cursor`].
    pub fn position(&self) -> ReplicaCursor {
        ReplicaCursor {
            epoch: self.shared.epoch.load(Ordering::SeqCst),
            seq: self.shared.cursor.load(Ordering::SeqCst),
        }
    }

    pub fn stats(&self) -> FollowerStats {
        FollowerStats {
            cursor: self.shared.cursor.load(Ordering::SeqCst),
            batches_applied: self.shared.batches_applied.load(Ordering::Relaxed),
            entries_applied: self.shared.entries_applied.load(Ordering::Relaxed),
            tombstones_applied: self.shared.tombstones_applied.load(Ordering::Relaxed),
            diff_entries_applied: self.shared.diff_entries_applied.load(Ordering::Relaxed),
            global_diffs_applied: self.shared.global_diffs_applied.load(Ordering::Relaxed),
            full_syncs: self.shared.full_syncs.load(Ordering::Relaxed),
            reconnects: self.shared.reconnects.load(Ordering::Relaxed),
            halted: self.shared.halted.load(Ordering::SeqCst),
            last_error: self
                .shared
                .last_error
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .clone(),
        }
    }

    /// Graceful shutdown (replication thread joined, listener closed);
    /// returns the final position for
    /// [`FollowerServer::start_at_cursor`] resume. Also runs on drop.
    pub fn shutdown(mut self) -> ReplicaCursor {
        self.stop_and_join();
        self.position()
        // `self` drops here: the wrapped server's own Drop performs its
        // graceful shutdown, and our Drop's stop_and_join is a no-op.
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

impl Drop for FollowerServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Bridge the follower's replication counters into the wrapped
/// server's metrics registry as scrape-time gauges. The closures
/// capture only `Arc<FollowerShared>`, which holds no reference back
/// to the registry — no cycle.
fn register_replica_gauges(metrics: &MetricsRegistry, shared: &Arc<FollowerShared>) {
    let s = shared.clone();
    metrics.gauge_fn("replica_cursor", None, move || s.cursor.load(Ordering::SeqCst) as f64);
    let s = shared.clone();
    metrics.gauge_fn("replica_batches_applied", None, move || {
        s.batches_applied.load(Ordering::Relaxed) as f64
    });
    let s = shared.clone();
    metrics.gauge_fn("replica_entries_applied", None, move || {
        s.entries_applied.load(Ordering::Relaxed) as f64
    });
    let s = shared.clone();
    metrics.gauge_fn("replica_tombstones_applied", None, move || {
        s.tombstones_applied.load(Ordering::Relaxed) as f64
    });
    let s = shared.clone();
    metrics.gauge_fn("replica_full_syncs", None, move || {
        s.full_syncs.load(Ordering::Relaxed) as f64
    });
    let s = shared.clone();
    metrics.gauge_fn("replica_reconnects", None, move || {
        s.reconnects.load(Ordering::Relaxed) as f64
    });
    let s = shared.clone();
    metrics.gauge_fn("replica_halted", None, move || {
        s.halted.load(Ordering::SeqCst) as u8 as f64
    });
}

/// Sleep `d` in small slices, returning early when `stop` is raised.
fn sleep_poll(d: Duration, stop: &AtomicBool) {
    let deadline = Instant::now() + d;
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let left = deadline.saturating_duration_since(Instant::now());
        if left.is_zero() {
            return;
        }
        std::thread::sleep(left.min(Duration::from_millis(5)));
    }
}

/// Outer connection loop: (re)connect, subscribe from the current
/// cursor, run the apply loop until it returns, back off, repeat —
/// until stopped or halted on a non-recoverable typed error.
fn replication_loop(
    primary: SocketAddr,
    registry: Arc<SketchRegistry<u64>>,
    cfg: FollowerConfig,
    stop: Arc<AtomicBool>,
    shared: Arc<FollowerShared>,
) {
    let mut first_attempt = true;
    loop {
        if stop.load(Ordering::SeqCst) || shared.halted.load(Ordering::SeqCst) {
            return;
        }
        if !first_attempt {
            shared.reconnects.fetch_add(1, Ordering::Relaxed);
            sleep_poll(cfg.reconnect_backoff, &stop);
            if stop.load(Ordering::SeqCst) {
                return;
            }
        }
        first_attempt = false;
        let mut stream = match TcpStream::connect(primary) {
            Ok(s) => s,
            Err(e) => {
                shared.record_error(format!("connect to primary {primary}: {e}"));
                continue;
            }
        };
        let _ = stream.set_read_timeout(Some(cfg.read_timeout));
        let _ = stream.set_write_timeout(Some(Duration::from_millis(50)));
        let _ = stream.set_nodelay(true);
        let epoch = shared.epoch.load(Ordering::SeqCst);
        let cursor = shared.cursor.load(Ordering::SeqCst);
        // Subscribe at delta wire v4: sealed batches additionally carry
        // the last-writer trace IDs. An older primary accepts the
        // higher generation byte and streams plain v3 — the trace entry
        // simply never appears.
        let subscribe = Request::Subscribe { epoch, cursor, wire: DELTA_WIRE_V4 }.encode();
        if !matches!(write_full(&mut stream, &subscribe, &stop), Ok(true)) {
            shared.record_error("subscribe write failed");
            continue;
        }
        crate::log_debug!("replica", "subscribed to {primary} at cursor {cursor} (epoch {epoch})");
        run_subscription(&mut stream, &registry, &stop, &shared);
    }
}

/// Apply one wire-v3 delta entry to the follower registry. Tombstones
/// evict (the primary dropped the key — TTL, budget, or explicit);
/// register diffs max-merge the changed registers; full sketches
/// max-merge whole (the batch path folds *runs* of full sketches
/// through [`SketchRegistry::merge_sketch_batch`] instead — this arm
/// is the flush-boundary singleton case). Malformed or
/// config-mismatched bodies surface as [`SketchError`]s for the caller
/// to halt on.
fn apply_delta(
    registry: &SketchRegistry<u64>,
    key: u64,
    delta: SketchDelta,
    shared: &FollowerShared,
) -> Result<(), SketchError> {
    match delta {
        SketchDelta::Tombstone => {
            // Absent keys are fine: the tombstone may describe a key
            // that never reached us (created and evicted between two
            // captures) or one a replayed batch already removed.
            registry.evict(&key);
            shared.tombstones_applied.fetch_add(1, Ordering::Relaxed);
            Ok(())
        }
        SketchDelta::RegisterDiff(bytes) => {
            let (cfg, entries) = decode_register_diff(&bytes)?;
            registry.apply_register_diff(key, cfg, &entries)?;
            shared.diff_entries_applied.fetch_add(1, Ordering::Relaxed);
            Ok(())
        }
        SketchDelta::Full(bytes) => {
            let sketch = HllSketch::from_bytes(&bytes)?;
            registry.merge_sketch(key, sketch)
        }
        SketchDelta::GlobalDiff(bytes) => {
            // Raises only the global union (the key field is
            // meaningless): words whose key was evicted on the primary
            // before the capture tick still count in this follower's
            // GlobalEstimate.
            let (cfg, entries) = decode_register_diff(&bytes)?;
            registry.merge_global_diff(cfg, &entries)?;
            shared.global_diffs_applied.fetch_add(1, Ordering::Relaxed);
            Ok(())
        }
    }
}

/// Flush an accumulated run of decoded full-sketch entries as one
/// batched merge ([`SketchRegistry::merge_sketch_batch`]: one shard
/// lock acquisition per shard run instead of one per key). `false`
/// halts replication, exactly as a per-entry rejection would — the
/// batch is config-validated whole before any state changes, so a
/// rejection leaves the registry as the per-entry path's first-entry
/// rejection did.
fn flush_full_run(
    registry: &SketchRegistry<u64>,
    shared: &FollowerShared,
    run: &mut Vec<(u64, HllSketch)>,
) -> bool {
    if run.is_empty() {
        return true;
    }
    if let Err(e) = registry.merge_sketch_batch(std::mem::take(run)) {
        shared.halt(format!("full-sketch delta run rejected: {e}"));
        return false;
    }
    true
}

/// Apply one delta batch (any wire generation, already normalized to
/// typed entries) if it advances the cursor. Entry order matters: an
/// evict-then-recreate ships tombstone first, then the new sketch.
/// Batches at or below the cursor are skipped whole — a replayed batch
/// could not interleave wrongly anyway (same entries), but skipping
/// keeps the tombstone-ordering argument a per-batch-once argument.
/// Returns `false` when replication has halted on a rejected entry.
///
/// Runs of consecutive [`SketchDelta::Full`] entries — the bulk of a
/// bootstrap-adjacent or sparse-heavy stream — decode up front and
/// apply through the registry's run-folding batch path; any other
/// delta kind flushes the pending run *first*, so cross-kind ordering
/// (the tombstone-before-recreate contract) is untouched: max-merge
/// commutes across the keys inside a run, but never across a
/// tombstone.
fn apply_batch(
    registry: &SketchRegistry<u64>,
    shared: &FollowerShared,
    seq: u64,
    entries: Vec<(u64, SketchDelta)>,
) -> bool {
    let applied = shared.cursor.load(Ordering::SeqCst);
    if seq > applied {
        let count = entries.len() as u64;
        let mut full_run: Vec<(u64, HllSketch)> = Vec::new();
        for (key, delta) in entries {
            if let SketchDelta::Full(bytes) = &delta {
                match HllSketch::from_bytes(bytes) {
                    Ok(sketch) => {
                        full_run.push((key, sketch));
                        continue;
                    }
                    Err(e) => {
                        shared.halt(format!("delta entry for key {key} rejected: {e}"));
                        return false;
                    }
                }
            }
            if !flush_full_run(registry, shared, &mut full_run) {
                return false;
            }
            if let Err(e) = apply_delta(registry, key, delta, shared) {
                // A delta that does not decode or match our config
                // cannot be fixed by retrying against the same primary:
                // halt, keep serving last-good state.
                shared.halt(format!("delta entry for key {key} rejected: {e}"));
                return false;
            }
        }
        if !flush_full_run(registry, shared, &mut full_run) {
            return false;
        }
        shared.cursor.store(seq, Ordering::SeqCst);
        shared.batches_applied.fetch_add(1, Ordering::Relaxed);
        shared.entries_applied.fetch_add(count, Ordering::Relaxed);
    }
    true
}

/// Apply frames from an established subscription until the stream
/// breaks, the primary misbehaves, or we are stopped/halted.
///
/// Inbound parsing is the same incremental [`FrameDecoder`] the
/// server's event loop runs: reads land in the decoder whatever their
/// size (the socket's read timeout is just the stop-flag poll tick),
/// and complete frames are pulled out in order — a batch split across
/// reads resumes instead of blocking mid-`read_exact`.
fn run_subscription(
    stream: &mut TcpStream,
    registry: &Arc<SketchRegistry<u64>>,
    stop: &AtomicBool,
    shared: &FollowerShared,
) {
    let mut decoder = FrameDecoder::new();
    let mut buf = vec![0u8; 16 * 1024];
    loop {
        if stop.load(Ordering::SeqCst) || shared.halted.load(Ordering::SeqCst) {
            return;
        }
        match stream.read(&mut buf) {
            Ok(0) => return, // peer closed → outer loop reconnects
            Ok(n) => decoder.extend(&buf[..n]),
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::WouldBlock
                        | io::ErrorKind::TimedOut
                        | io::ErrorKind::Interrupted
                ) =>
            {
                continue // idle tick: re-check stop, keep waiting
            }
            Err(_) => return, // disconnect → outer loop reconnects
        }
        loop {
            if stop.load(Ordering::SeqCst) || shared.halted.load(Ordering::SeqCst) {
                return;
            }
            let (opcode, payload) = match decoder.next_frame() {
                Ok(Some(frame)) => frame,
                Ok(None) => break, // need more bytes
                Err(e) => {
                    // Broken framing from the primary. A version this
                    // follower does not decode cannot be fixed by
                    // reconnecting (the same bytes replay forever):
                    // halt. Torn magic/oversize reconnects like any
                    // stream corruption.
                    let why = format!("undecodable frame from primary: {e}");
                    if matches!(e, ProtocolError::BadVersion(_)) {
                        shared.halt(why);
                    } else {
                        shared.record_error(why);
                    }
                    return;
                }
            };
            if !apply_frame(stream, registry, stop, shared, opcode, &payload) {
                return;
            }
        }
    }
}

/// Decode and apply one frame of the subscription stream; `false` ends
/// the subscription (the outer loop decides between reconnect and
/// halt via the `halted` flag).
fn apply_frame(
    stream: &mut TcpStream,
    registry: &Arc<SketchRegistry<u64>>,
    stop: &AtomicBool,
    shared: &FollowerShared,
    opcode: u8,
    payload: &[u8],
) -> bool {
    let resp = match Response::decode(opcode, payload) {
        Ok(resp) => resp,
        Err(e) => {
            // An unknown opcode or frame version is a primary speaking
            // a newer wire than this follower decodes — reconnecting
            // would replay the same bytes forever.
            let why = format!("undecodable frame from primary: {e}");
            if matches!(e, ProtocolError::BadOpcode(_) | ProtocolError::BadVersion(_)) {
                shared.halt(why);
            } else {
                shared.record_error(why);
            }
            return false;
        }
    };
    match resp {
            Response::FullSync { epoch, cursor, body } => {
                // A full sync *replaces* local state (keys absent from
                // the image were evicted on the primary while our
                // tombstone batches rotated out of retention — merging
                // would resurrect them forever). The image is validated
                // whole before anything is cleared, so the halt path
                // below still leaves last-good state serving.
                match snapshot::replace_from_bytes(registry, &body) {
                    Ok(keys) => {
                        // The image resets our position into the
                        // primary's (possibly new) log incarnation.
                        shared.epoch.store(epoch, Ordering::SeqCst);
                        shared.cursor.store(cursor, Ordering::SeqCst);
                        shared.full_syncs.fetch_add(1, Ordering::Relaxed);
                        crate::log_debug!(
                            "replica",
                            "full sync applied: {keys} keys, cursor {cursor} (epoch {epoch})"
                        );
                    }
                    Err(e) => {
                        // A sync that does not apply cleanly (config or
                        // seed mismatch, corrupt image) cannot be fixed
                        // by retrying against the same primary: halt,
                        // keep serving last-good state.
                        shared.halt(format!("full sync rejected: {e}"));
                        return false;
                    }
                }
            }
            Response::DeltaBatch { seq, entries } => {
                // Legacy wire-v2 stream (old primary): every entry is a
                // full sketch and evictions never arrive — semantically
                // a v3 batch of Full entries, so it shares the apply
                // path.
                let typed: Vec<(u64, SketchDelta)> = entries
                    .into_iter()
                    .map(|(key, bytes)| (key, SketchDelta::Full(bytes)))
                    .collect();
                if !apply_batch(registry, shared, seq, typed) {
                    return false;
                }
            }
            Response::DeltaBatchV3 { seq, entries, seal_unix_ns, writer_traces } => {
                // The apply span joins the batch's first sealed writer
                // trace (empty on a v3 primary or untraced writes), so
                // one trace ID stitches the write's primary-side spans
                // to this follower's apply. Also feeds the
                // `stage_latency_ns{stage="follower_apply"}` series.
                let applied = {
                    let _span = Span::enter_timed(
                        Stage::FollowerApply,
                        writer_traces.first().copied().unwrap_or(0),
                        &shared.apply_ns,
                    )
                    .with_payload(seq);
                    apply_batch(registry, shared, seq, entries)
                };
                if !applied {
                    return false;
                }
                // Batches from primaries new enough to stamp a seal
                // time feed the cross-process replication-latency
                // histogram (0 = unstamped legacy batch).
                if seal_unix_ns != 0 {
                    shared
                        .seal_to_apply_ns
                        .record(crate::obs::unix_time_ns().saturating_sub(seal_unix_ns));
                }
            }
            Response::Error { code, message } => {
                if matches!(
                    code,
                    ErrorCode::Unsupported
                        | ErrorCode::ReadOnly
                        | ErrorCode::Internal
                        | ErrorCode::Malformed
                ) {
                    // Subscribed to something that will never replicate
                    // to us: not a primary, an image past the in-band
                    // full-sync cap, or a primary too old to decode our
                    // subscribe frame (Malformed) — retrying replays
                    // the identical bytes, and each retry costs the
                    // primary work.
                    shared.halt(format!("primary answered {code:?}: {message}"));
                } else {
                    shared.record_error(format!("primary answered {code:?}: {message}"));
                }
                return false;
            }
            other => {
                shared.record_error(format!(
                    "unexpected {} frame on the subscription stream",
                    other.label()
                ));
                return false;
            }
        }
    let ack = Request::ReplicaAck { cursor: shared.cursor.load(Ordering::SeqCst) }.encode();
    matches!(write_full(stream, &ack, stop), Ok(true))
}
