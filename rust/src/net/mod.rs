//! Network substrate for the NIC deployment (Section VII): link
//! parameters, a discrete-event TCP flow simulator with receiver flow
//! control / drops / go-back-N retransmission, and the coupled
//! NIC + HLL-engine model that regenerates Table IV.
//!
//! Everything in this module is *simulation* (what the paper's hardware
//! would do at 100 Gbit/s). The real-socket serving path — an actual
//! TCP server/client in front of the sketch registry, with the same
//! keyed streams [`KeyedFlowGen`] generates — lives in
//! [`crate::server`].

pub mod link;
pub mod nic;
pub mod tcp;

pub use link::LinkParams;
pub use nic::{run_timing, run_with_data, table4_sweep, KeyedFlowGen, NicConfig, NicRun};
pub use tcp::{TcpSim, TcpStats};
