//! The FPGA-NIC deployment (Fig 5): network stack + controller + HLL
//! engine in the network clock domain.
//!
//! Couples the TCP flow simulator (timing, drops, flow control) with the
//! functional multi-pipeline engine (sketch contents) so an end-to-end
//! run produces both the paper's Table-IV throughput row *and* a real
//! cardinality estimate for the streamed data.

use super::link::LinkParams;
use super::tcp::{TcpSim, TcpStats};
use crate::fpga::{theoretical_throughput_bytes_per_s, ParallelHll, ParallelResult};
use crate::hll::HllConfig;
use crate::util::{Xoshiro256StarStar, Zipf};

/// Deterministic keyed-flow traffic source: `(flow key, word)` pairs with
/// Zipf-distributed flow popularity — the NIC-side workload for the
/// multi-tenant registry path ("how many distinct items per flow?").
/// Real NIC traffic is heavily skewed across flows, which is exactly
/// what stresses the registry's shard striping and the hot buckets of
/// the global concurrent sketch; `skew` is the Zipf exponent (≈1.07 for
/// web-like popularity).
#[derive(Debug, Clone)]
pub struct KeyedFlowGen {
    rng: Xoshiro256StarStar,
    flows: Zipf,
    key_domain: u64,
}

impl KeyedFlowGen {
    pub fn new(keys: u64, skew: f64, seed: u64) -> Self {
        assert!(keys >= 1);
        Self {
            rng: Xoshiro256StarStar::seed_from_u64(seed),
            flows: Zipf::new(keys, skew),
            key_domain: keys,
        }
    }

    pub fn key_domain(&self) -> u64 {
        self.key_domain
    }

    /// Next `(flow key, payload word)` pair. Keys are `0..key_domain`,
    /// key 0 the hottest flow.
    pub fn next_pair(&mut self) -> (u64, u32) {
        let key = self.flows.sample(&mut self.rng) - 1; // rank 1 → key 0
        (key, self.rng.next_u32())
    }

    /// Produce a batch of `n` pairs (the unit the keyed coordinator
    /// feeds).
    pub fn batch(&mut self, n: usize) -> Vec<(u64, u32)> {
        (0..n).map(|_| self.next_pair()).collect()
    }

    /// As [`KeyedFlowGen::batch`], but grouped per key into
    /// `(key, words)` ingest batches of at most `max_batch` words each,
    /// sorted by key — the unit of work the serving layer's
    /// `InsertBatch` RPC takes (used by the server tests, bench and
    /// example).
    pub fn batched(&mut self, n: usize, max_batch: usize) -> Vec<(u64, Vec<u32>)> {
        assert!(max_batch >= 1);
        let mut by_key: std::collections::HashMap<u64, Vec<u32>> =
            std::collections::HashMap::new();
        for (key, word) in self.batch(n) {
            by_key.entry(key).or_default().push(word);
        }
        let mut out: Vec<(u64, Vec<u32>)> = Vec::new();
        for (key, words) in by_key {
            if words.len() <= max_batch {
                out.push((key, words));
            } else {
                for chunk in words.chunks(max_batch) {
                    out.push((key, chunk.to_vec()));
                }
            }
        }
        out.sort_by_key(|&(key, _)| key);
        out
    }
}

/// Configuration of the NIC deployment.
#[derive(Debug, Clone, Copy)]
pub struct NicConfig {
    pub link: LinkParams,
    pub hll: HllConfig,
    /// Number of parallel HLL pipelines behind the network stack.
    pub pipelines: usize,
}

impl NicConfig {
    pub fn paper(pipelines: usize) -> Self {
        Self { link: LinkParams::paper(), hll: HllConfig::PAPER, pipelines }
    }

    /// The engine's drain rate as seen by the rx FIFO.
    pub fn consumer_bytes_per_s(&self) -> f64 {
        theoretical_throughput_bytes_per_s(self.pipelines)
    }
}

/// Timing + functional outcome of one NIC run.
#[derive(Debug, Clone)]
pub struct NicRun {
    pub tcp: TcpStats,
    /// Functional result (sketch + estimate); `None` for timing-only runs.
    pub hll: Option<ParallelResult>,
    /// Constant computation-phase time appended after the stream ends
    /// (2^p × 3.1 ns — the paper's 203 µs).
    pub drain_seconds: f64,
}

impl NicRun {
    /// Sustained receive throughput (the Table IV metric).
    pub fn throughput_bytes_per_s(&self) -> f64 {
        self.tcp.goodput_bytes_per_s()
    }
}

/// Simulate streaming `total_bytes` of timing-only traffic.
pub fn run_timing(cfg: &NicConfig, total_bytes: u64) -> NicRun {
    let tcp = TcpSim::new(cfg.link, cfg.consumer_bytes_per_s(), total_bytes).run();
    let drain = crate::fpga::ClockDomain::NETWORK.cycles_to_seconds(cfg.hll.m() as u64 + 32);
    NicRun { tcp, hll: None, drain_seconds: drain }
}

/// Simulate streaming an actual word stream: TCP timing from the byte
/// count, sketch contents from the functional parallel engine.
pub fn run_with_data(cfg: &NicConfig, words: &[u32]) -> NicRun {
    let tcp = TcpSim::new(
        cfg.link,
        cfg.consumer_bytes_per_s(),
        (words.len() * 4) as u64,
    )
    .run();
    let mut engine = ParallelHll::new(cfg.hll, cfg.pipelines);
    engine.feed(words);
    let result = engine.finish();
    let drain = result.clock.cycles_to_seconds(result.drain_cycles);
    NicRun { tcp, hll: Some(result), drain_seconds: drain }
}

/// The Table IV sweep: sustained throughput per pipeline count.
pub fn table4_sweep(pipeline_counts: &[usize], bytes_per_run: u64) -> Vec<(usize, NicRun)> {
    pipeline_counts
        .iter()
        .map(|&k| (k, run_timing(&NicConfig::paper(k), bytes_per_run)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256StarStar;

    #[test]
    fn functional_run_estimates_cardinality() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(31);
        let n = 50_000usize;
        let mut set = std::collections::HashSet::with_capacity(n);
        while set.len() < n {
            set.insert(rng.next_u32());
        }
        let words: Vec<u32> = set.into_iter().collect();
        let cfg = NicConfig::paper(4);
        let run = run_with_data(&cfg, &words);
        let est = run.hll.as_ref().unwrap().breakdown.estimate;
        let rel = (est - n as f64).abs() / n as f64;
        assert!(rel < 0.02, "estimate {est} vs {n}");
        assert_eq!(run.tcp.delivered_bytes, (words.len() * 4) as u64);
    }

    #[test]
    fn drain_constant_203us() {
        let run = run_timing(&NicConfig::paper(8), 1 << 20);
        assert!((run.drain_seconds - 203e-6).abs() < 2e-6);
    }

    #[test]
    fn keyed_flow_gen_is_deterministic_and_skewed() {
        let mut a = KeyedFlowGen::new(1_000, 1.2, 9);
        let mut b = KeyedFlowGen::new(1_000, 1.2, 9);
        assert_eq!(a.batch(500), b.batch(500));

        let mut c = KeyedFlowGen::new(1_000, 1.2, 10);
        let batch = c.batch(4_000);
        assert!(batch.iter().all(|&(k, _)| k < 1_000));
        // Zipf head: the hottest 10 flows carry a large share.
        let head = batch.iter().filter(|&&(k, _)| k < 10).count();
        assert!(head > 800, "zipf head mass too small: {head}");
        assert_eq!(c.key_domain(), 1_000);
    }

    #[test]
    fn keyed_flows_feed_the_registry() {
        use crate::registry::{RegistryConfig, SketchRegistry};
        let reg: SketchRegistry<u64> =
            SketchRegistry::new(RegistryConfig::default()).unwrap();
        let mut gen = KeyedFlowGen::new(64, 1.07, 3);
        let pairs = gen.batch(10_000);
        reg.ingest_pairs(&pairs);
        assert!(reg.len() <= 64 && reg.len() > 10);
        assert_eq!(reg.stats().words(), 10_000);
    }

    #[test]
    fn table4_shape() {
        // The qualitative Table IV shape: collapse at k≤2, recovery at
        // k=4, monotone growth toward the window ceiling.
        let rows = table4_sweep(&[1, 2, 4, 8, 10, 16], 8 << 20);
        let tp: Vec<f64> = rows.iter().map(|(_, r)| r.throughput_bytes_per_s() / 1e9).collect();
        assert!(tp[0] < 1.0, "k=1 collapsed: {tp:?}");
        assert!(tp[1] < 1.0, "k=2 collapsed: {tp:?}");
        assert!(tp[2] > 3.0, "k=4 recovered: {tp:?}");
        assert!(tp[5] > 8.0, "k=16 near ceiling: {tp:?}");
        for w in tp.windows(2) {
            assert!(w[1] > w[0] * 0.95, "roughly monotone: {tp:?}");
        }
    }
}
