//! Link and endpoint parameters for the NIC deployment (Section VII).

/// Physical + protocol parameters of the Host-A → FPGA-NIC path.
#[derive(Debug, Clone, Copy)]
pub struct LinkParams {
    /// Line rate in bytes/s (100 Gbit/s).
    pub line_rate_bytes_per_s: f64,
    /// One-way propagation + switching + endpoint pipeline delay.
    pub one_way_delay_s: f64,
    /// TCP maximum segment size (payload bytes). The FPGA stack [42]
    /// uses jumbo frames.
    pub mss: u32,
    /// Per-segment wire overhead (Ethernet + IP + TCP headers, preamble,
    /// IFG).
    pub header_bytes: u32,
    /// Receiver (FPGA NIC) on-chip rx buffer in bytes. Small by design:
    /// BRAM is precious (Table III keeps HLL under 6%).
    pub rx_buffer_bytes: u32,
    /// Sender retransmission timeout.
    pub rto_s: f64,
    /// Initial slow-start threshold in bytes.
    pub initial_ssthresh: u32,
    /// Overflow hysteresis: once the ingress FIFO overruns, the MAC gate
    /// drops *all* frames until occupancy falls below this fraction of
    /// the capacity (hardware FIFOs reopen on a watermark, not on
    /// byte-granular space). This is what turns slow drains (k ≤ 2) into
    /// RTO cycles: the drop window outlasts any retransmission attempt.
    pub reopen_watermark: f64,
}

impl Default for LinkParams {
    fn default() -> Self {
        Self::paper()
    }
}

impl LinkParams {
    /// Calibrated to Section VII's testbed: 100 Gbit/s link, jumbo
    /// frames, a 256 KiB on-chip rx FIFO and ~14 µs one-way latency
    /// (host stack + switch + FPGA ingress). With these, the
    /// window-limited ceiling buffer/RTT ≈ 9.3 GByte/s matches the
    /// paper's 16-pipeline figure (9.35), and the overshoot criterion
    /// (line − consume)·RTT > buffer reproduces the collapse at k ≤ 2:
    /// k=2 overshoots by 278 KiB > 256 KiB while k=4's 206 KiB fits.
    pub fn paper() -> Self {
        Self {
            line_rate_bytes_per_s: 12.5e9,
            one_way_delay_s: 14e-6,
            mss: 4096,
            header_bytes: 78, // Eth(14)+IP(20)+TCP(20)+FCS(4)+preamble/IFG(20)
            rx_buffer_bytes: 256 << 10,
            rto_s: 2e-3,
            initial_ssthresh: 1 << 20,
            reopen_watermark: 0.5,
        }
    }

    /// Round-trip time excluding serialization.
    pub fn rtt_s(&self) -> f64 {
        2.0 * self.one_way_delay_s
    }

    /// Wire time of one full segment.
    pub fn segment_wire_s(&self) -> f64 {
        (self.mss + self.header_bytes) as f64 / self.line_rate_bytes_per_s
    }

    /// The flow-control ceiling: at most one buffer's worth of payload
    /// can be in flight per RTT.
    pub fn window_limited_bytes_per_s(&self) -> f64 {
        self.rx_buffer_bytes as f64 / (self.rtt_s() + self.segment_wire_s())
    }

    /// Overshoot bound: data the sender can emit beyond the consumer's
    /// drain during one RTT. If this exceeds the rx buffer, drops are
    /// unavoidable and throughput collapses (the paper's k ≤ 2 rows).
    pub fn overshoot_bytes(&self, consumer_bytes_per_s: f64) -> f64 {
        (self.line_rate_bytes_per_s - consumer_bytes_per_s).max(0.0) * self.rtt_s()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_ceiling_near_paper_16_pipeline_rate() {
        let p = LinkParams::paper();
        let gb = p.window_limited_bytes_per_s() / 1e9;
        // Paper Table IV: 9.35 GByte/s at 16 pipelines.
        assert!((gb - 9.35).abs() < 0.8, "{gb}");
    }

    #[test]
    fn consumer_vs_line_rate_regimes() {
        // k ≤ 9: the engine drains slower than the line delivers →
        // overflow-prone; k = 16 drains above line rate → loss-free.
        let p = LinkParams::paper();
        let per_pipe = crate::fpga::theoretical_throughput_bytes_per_s(1);
        assert!(9.0 * per_pipe < p.line_rate_bytes_per_s);
        assert!(16.0 * per_pipe > p.line_rate_bytes_per_s);
        // Overshoot diagnostic is monotone decreasing in k.
        assert!(p.overshoot_bytes(per_pipe) > p.overshoot_bytes(4.0 * per_pipe));
        assert_eq!(p.overshoot_bytes(20.0 * per_pipe), 0.0);
    }
}
