//! Discrete-event TCP simulator for the NIC deployment (Section VII).
//!
//! Models the Host-A sender (Mellanox side) streaming a data set over a
//! 100 Gbit/s link into the FPGA NIC, whose on-chip rx FIFO is drained
//! by the k-pipeline HLL engine at k × 1.288 GByte/s.
//!
//! The drop mechanism follows the paper's narrative ("the integrated HLL
//! processing induces significant back-pressure on the network stack,
//! which starts dropping packets"): the FPGA stack advertises its static
//! TCP window (it does not propagate application back-pressure into the
//! window), so when the engine drains slower than the line delivers, the
//! ingress FIFO overflows and frames are dropped *silently at the MAC*,
//! before the TCP engine — no duplicate acks are generated for them.
//! Sustained overflow therefore silences the ack stream and forces RTO
//! slow-start cycles (the catastrophic k ≤ 2 rows of Table IV), while
//! brief overflows are healed by fast retransmit (the intermediate k).
//! With no loss at all, throughput is window-limited to W/RTT — the
//! k = 16 plateau.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::link::LinkParams;

/// Simulation outcome.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TcpStats {
    /// Payload bytes delivered in order (== requested bytes on success).
    pub delivered_bytes: u64,
    /// Simulated duration until the last byte was accepted.
    pub duration_s: f64,
    /// Frames dropped at the NIC ingress (buffer full).
    pub drops: u64,
    /// Out-of-order segments discarded by the go-back-N receiver.
    pub discards: u64,
    /// Segments retransmitted by the sender.
    pub retransmits: u64,
    /// RTO events (each collapses the congestion window to 1 MSS).
    pub timeouts: u64,
    /// Fast-retransmit events (3 duplicate acks).
    pub fast_retransmits: u64,
    /// Total segments that crossed the wire (incl. retransmissions).
    pub segments_sent: u64,
}

impl TcpStats {
    pub fn goodput_bytes_per_s(&self) -> f64 {
        self.delivered_bytes as f64 / self.duration_s
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Event {
    /// Segment (seq, payload_len) arrives at the NIC.
    ArriveNic { seq: u64, len: u32 },
    /// Cumulative ack arrives at the sender.
    ArriveAck { ack: u64 },
    /// Retransmission timer (valid iff epoch matches).
    Rto { epoch: u64 },
}

#[derive(Debug, Clone, Copy)]
struct Scheduled {
    t: f64,
    tie: u64,
    ev: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.tie == other.tie
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t
            .partial_cmp(&other.t)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(self.tie.cmp(&other.tie))
    }
}

/// One sender → NIC flow.
pub struct TcpSim {
    p: LinkParams,
    /// NIC consumer (HLL engine) drain rate, bytes/s.
    consumer_bytes_per_s: f64,
    // --- sender state ---
    snd_una: u64,
    snd_nxt: u64,
    cwnd: f64,
    ssthresh: f64,
    sender_busy_until: f64,
    rto_epoch: u64,
    dup_acks: u32,
    /// Fast-recovery guard: no second fast retransmit until the ack
    /// passes the point where the first one was triggered.
    recovery_point: u64,
    in_recovery: bool,
    // --- receiver state ---
    rcv_nxt: u64,
    buf_occ: f64,
    last_drain_t: f64,
    /// Overflow hysteresis: gate closed until the FIFO drains below the
    /// reopen watermark.
    gate_closed: bool,
    /// Out-of-order reassembly intervals [(start, end)), sorted — the
    /// FPGA stack's OOO engine. Bytes here occupy the FIFO.
    ooo: Vec<(u64, u64)>,
    // --- infra ---
    total_bytes: u64,
    heap: BinaryHeap<Reverse<Scheduled>>,
    tie: u64,
    stats: TcpStats,
}

impl TcpSim {
    pub fn new(p: LinkParams, consumer_bytes_per_s: f64, total_bytes: u64) -> Self {
        Self {
            p,
            consumer_bytes_per_s,
            snd_una: 0,
            snd_nxt: 0,
            cwnd: p.mss as f64 * 10.0, // IW10
            ssthresh: p.initial_ssthresh as f64,
            sender_busy_until: 0.0,
            rto_epoch: 0,
            dup_acks: 0,
            recovery_point: 0,
            in_recovery: false,
            rcv_nxt: 0,
            buf_occ: 0.0,
            last_drain_t: 0.0,
            gate_closed: false,
            ooo: Vec::new(),
            total_bytes,
            heap: BinaryHeap::new(),
            tie: 0,
            stats: TcpStats {
                delivered_bytes: 0,
                duration_s: 0.0,
                drops: 0,
                discards: 0,
                retransmits: 0,
                timeouts: 0,
                fast_retransmits: 0,
                segments_sent: 0,
            },
        }
    }

    fn schedule(&mut self, t: f64, ev: Event) {
        self.tie += 1;
        self.heap.push(Reverse(Scheduled { t, tie: self.tie, ev }));
    }

    fn arm_rto(&mut self, now: f64) {
        self.rto_epoch += 1;
        let epoch = self.rto_epoch;
        self.schedule(now + self.p.rto_s, Event::Rto { epoch });
    }

    /// Emit as many segments as the windows allow, starting at `now`.
    fn try_send(&mut self, now: f64) {
        // The FPGA stack advertises its static window; the sender's limit
        // is min(cwnd, W) beyond the last cumulative ack.
        let win = self.cwnd.min(self.p.rx_buffer_bytes as f64).max(self.p.mss as f64) as u64;
        let limit = self.snd_una + win;
        let mut sent_any = false;
        while self.snd_nxt < self.total_bytes && self.snd_nxt < limit {
            let len = self
                .p
                .mss
                .min((self.total_bytes - self.snd_nxt) as u32)
                .min((limit - self.snd_nxt) as u32);
            if len == 0 {
                break;
            }
            let start = self.sender_busy_until.max(now);
            let done = start + (len + self.p.header_bytes) as f64 / self.p.line_rate_bytes_per_s;
            self.sender_busy_until = done;
            let seq = self.snd_nxt;
            self.schedule(done + self.p.one_way_delay_s, Event::ArriveNic { seq, len });
            self.snd_nxt += len as u64;
            self.stats.segments_sent += 1;
            sent_any = true;
        }
        if sent_any {
            self.arm_rto(now);
        }
    }

    /// Drain the NIC ingress FIFO up to time `t`.
    fn drain(&mut self, t: f64) {
        let dt = (t - self.last_drain_t).max(0.0);
        self.buf_occ = (self.buf_occ - dt * self.consumer_bytes_per_s).max(0.0);
        self.last_drain_t = t;
    }

    fn on_arrive_nic(&mut self, now: f64, seq: u64, len: u32) {
        self.drain(now);
        if self.gate_closed {
            if self.buf_occ <= self.p.reopen_watermark * self.p.rx_buffer_bytes as f64 {
                self.gate_closed = false;
            } else {
                // Gate still closed: everything is dropped silently.
                self.stats.drops += 1;
                return;
            }
        }
        if self.buf_occ + len as f64 > self.p.rx_buffer_bytes as f64 {
            // MAC-level overflow: silent drop, close the gate.
            self.gate_closed = true;
            self.stats.drops += 1;
            return;
        }
        if seq == self.rcv_nxt {
            self.buf_occ += len as f64;
            self.rcv_nxt += len as u64;
            // Jump over contiguous OOO-reassembled data.
            while let Some(&(s, e)) = self.ooo.first() {
                if s <= self.rcv_nxt {
                    self.rcv_nxt = self.rcv_nxt.max(e);
                    self.ooo.remove(0);
                } else {
                    break;
                }
            }
        } else if seq > self.rcv_nxt {
            // Out-of-order: the stack's OOO engine buffers it (it is
            // already in the FIFO) and emits a duplicate cumulative ack.
            self.buf_occ += len as f64;
            self.insert_ooo(seq, seq + len as u64);
            self.stats.discards += 1; // counted as "held OOO"
        }
        // seq < rcv_nxt: stale retransmission; ack cumulatively. The
        // payload is dropped before the FIFO (duplicate detection).
        self.schedule(now + self.p.one_way_delay_s, Event::ArriveAck { ack: self.rcv_nxt });
    }

    /// Insert-and-merge an interval into the sorted OOO list.
    fn insert_ooo(&mut self, start: u64, end: u64) {
        let pos = self.ooo.partition_point(|&(s, _)| s < start);
        self.ooo.insert(pos, (start, end));
        // Merge neighbours (duplicates from go-back-N resends overlap).
        let mut i = 0;
        while i + 1 < self.ooo.len() {
            let (s0, e0) = self.ooo[i];
            let (s1, e1) = self.ooo[i + 1];
            if s1 <= e0 {
                self.ooo[i] = (s0, e0.max(e1));
                self.ooo.remove(i + 1);
            } else {
                i += 1;
            }
        }
    }

    fn on_arrive_ack(&mut self, now: f64, ack: u64) {
        if ack > self.snd_una {
            let newly = (ack - self.snd_una) as f64;
            self.snd_una = ack;
            // The receiver's OOO engine can cumulative-ack past data we
            // were about to resend — skip ahead.
            if ack > self.snd_nxt {
                self.snd_nxt = ack;
            }
            self.dup_acks = 0;
            if self.in_recovery && ack >= self.recovery_point {
                self.in_recovery = false;
            }
            if !self.in_recovery {
                // Slow start below ssthresh, else additive increase.
                if self.cwnd < self.ssthresh {
                    self.cwnd += newly;
                } else {
                    self.cwnd += (self.p.mss as f64) * newly / self.cwnd;
                }
            }
            if self.snd_una < self.snd_nxt {
                self.arm_rto(now);
            } else {
                self.rto_epoch += 1; // disarm
            }
            self.try_send(now);
            return;
        }
        // Duplicate ack.
        if self.snd_una >= self.snd_nxt {
            return; // nothing outstanding (stray)
        }
        self.dup_acks += 1;
        if self.dup_acks == 3 && !self.in_recovery {
            // Fast retransmit: halve the window and go-back-N resend from
            // the hole. The OOO receiver will cumulative-ack over already
            // held data, so only the lost range actually re-crosses.
            self.stats.fast_retransmits += 1;
            self.stats.retransmits += (self.snd_nxt - self.snd_una).div_ceil(self.p.mss as u64);
            self.ssthresh = (self.cwnd / 2.0).max(2.0 * self.p.mss as f64);
            self.cwnd = self.ssthresh;
            self.recovery_point = self.snd_nxt;
            self.in_recovery = true;
            self.dup_acks = 0;
            self.snd_nxt = self.snd_una;
            self.try_send(now);
        }
    }

    fn on_rto(&mut self, now: f64, epoch: u64) {
        if epoch != self.rto_epoch || self.snd_una >= self.total_bytes {
            return;
        }
        if self.snd_una >= self.snd_nxt {
            return; // nothing outstanding
        }
        // Timeout: collapse to 1 MSS, slow-start again, go-back-N resend.
        self.stats.timeouts += 1;
        self.stats.retransmits += (self.snd_nxt - self.snd_una).div_ceil(self.p.mss as u64);
        self.ssthresh = (self.cwnd / 2.0).max(2.0 * self.p.mss as f64);
        self.cwnd = self.p.mss as f64;
        self.in_recovery = false;
        self.dup_acks = 0;
        self.snd_nxt = self.snd_una;
        self.try_send(now);
    }

    /// Run to completion; returns the stats.
    pub fn run(mut self) -> TcpStats {
        let mut now = 0.0f64;
        self.try_send(now);
        let mut guard = 0u64;
        while self.snd_una < self.total_bytes {
            let Some(Reverse(next)) = self.heap.pop() else {
                panic!(
                    "tcp sim deadlock at t={now}: una={} nxt={}",
                    self.snd_una, self.snd_nxt
                );
            };
            now = next.t;
            match next.ev {
                Event::ArriveNic { seq, len } => self.on_arrive_nic(now, seq, len),
                Event::ArriveAck { ack } => self.on_arrive_ack(now, ack),
                Event::Rto { epoch } => self.on_rto(now, epoch),
            }
            guard += 1;
            assert!(guard < 500_000_000, "tcp sim runaway: {guard} events, t={now}");
        }
        self.stats.delivered_bytes = self.total_bytes;
        self.stats.duration_s = now;
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::theoretical_throughput_bytes_per_s;

    fn consumer(k: usize) -> f64 {
        theoretical_throughput_bytes_per_s(k)
    }

    fn run_k(k: usize, mb: u64) -> TcpStats {
        TcpSim::new(LinkParams::paper(), consumer(k), mb << 20).run()
    }

    #[test]
    fn delivers_all_bytes() {
        let s = run_k(4, 8);
        assert_eq!(s.delivered_bytes, 8 << 20);
        assert!(s.duration_s > 0.0);
    }

    #[test]
    fn small_k_collapses() {
        // Paper Table IV: k ∈ {1,2} → 0.05 / 0.12 GByte/s — catastrophic
        // RTO cycling. Assert the collapse regime: goodput an order of
        // magnitude below the engine's drain capacity, with drops and
        // timeouts.
        for k in [1usize, 2] {
            let s = run_k(k, 4);
            let gbyte = s.goodput_bytes_per_s() / 1e9;
            let capacity = consumer(k) / 1e9;
            assert!(gbyte < capacity * 0.35, "k={k}: {gbyte} vs capacity {capacity}");
            assert!(s.drops > 0, "k={k} must drop");
            assert!(s.timeouts > 0, "k={k} must hit RTO");
        }
    }

    #[test]
    fn k4_recovers_to_multi_gbyte() {
        let s = run_k(4, 16);
        let gbyte = s.goodput_bytes_per_s() / 1e9;
        assert!(gbyte > 3.0, "k=4: {gbyte} GB/s");
    }

    #[test]
    fn k16_hits_window_ceiling_cleanly() {
        let s = run_k(16, 32);
        let gbyte = s.goodput_bytes_per_s() / 1e9;
        let ceiling = LinkParams::paper().window_limited_bytes_per_s() / 1e9;
        assert!(gbyte > 8.0, "k=16: {gbyte} GB/s");
        assert!(gbyte <= ceiling * 1.05, "k=16: {gbyte} above ceiling {ceiling}");
        assert_eq!(s.drops, 0, "k=16 must not overflow");
        assert_eq!(s.timeouts, 0);
    }

    #[test]
    fn throughput_grows_with_k() {
        let gs: Vec<f64> = [1usize, 2, 4, 8, 16]
            .iter()
            .map(|&k| run_k(k, 8).goodput_bytes_per_s())
            .collect();
        for w in gs.windows(2) {
            assert!(w[1] > w[0] * 0.95, "non-growth: {gs:?}");
        }
        // The k=2 → k=4 jump is the dramatic regime change of Table IV.
        assert!(gs[2] / gs[1] > 5.0, "collapse→recovery jump missing: {gs:?}");
    }

    #[test]
    fn no_drops_means_no_retransmits() {
        let s = run_k(16, 8);
        assert_eq!(s.drops, 0);
        assert_eq!(s.retransmits, 0);
        assert_eq!(s.fast_retransmits, 0);
    }

    #[test]
    fn conservation_under_heavy_loss() {
        // Even in the collapse regime every byte is eventually delivered
        // exactly once (go-back-N is lossless end-to-end).
        let s = run_k(1, 2);
        assert_eq!(s.delivered_bytes, 2 << 20);
        assert!(s.retransmits > 0);
    }
}
