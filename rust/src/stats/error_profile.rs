//! The statistical profiling harness of Section IV (Fig 1): relative
//! estimation error of HLL across cardinalities, hash widths and
//! precisions, aggregated over independent trials.

use crate::hll::{EstimatorKind, HllConfig, HllSketch};
use crate::stats::datasets::DistinctStream;

/// Error statistics at one (config, cardinality) point.
#[derive(Debug, Clone, Copy)]
pub struct ErrorPoint {
    pub cardinality: u64,
    pub trials: usize,
    /// Relative errors |est − n| / n: min, median, max over trials.
    pub min: f64,
    pub median: f64,
    pub max: f64,
    /// Mean absolute relative error — the estimator-comparison metric
    /// (bias and spread folded into one number).
    pub mean: f64,
    /// Root-mean-square relative error — the empirical "standard error"
    /// comparable to the analytic 1.04/√m.
    pub rms: f64,
}

/// One Fig-1 curve: a config swept over cardinalities.
#[derive(Debug, Clone)]
pub struct ErrorCurve {
    pub config: HllConfig,
    pub points: Vec<ErrorPoint>,
}

/// Log-spaced cardinalities from 10^lo to 10^hi, `per_decade` points per
/// decade.
pub fn log_spaced_cardinalities(lo_exp: u32, hi_exp: u32, per_decade: u32) -> Vec<u64> {
    let mut out = Vec::new();
    let steps = (hi_exp - lo_exp) * per_decade;
    for s in 0..=steps {
        let exp = lo_exp as f64 + s as f64 / per_decade as f64;
        let n = 10f64.powf(exp).round() as u64;
        if out.last() != Some(&n) {
            out.push(n);
        }
    }
    out
}

/// Collapse one trial set of relative errors into an [`ErrorPoint`].
fn summarize(mut errors: Vec<f64>, cardinality: u64) -> ErrorPoint {
    errors.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let trials = errors.len();
    let mean = errors.iter().sum::<f64>() / trials as f64;
    let rms = (errors.iter().map(|e| e * e).sum::<f64>() / trials as f64).sqrt();
    ErrorPoint {
        cardinality,
        trials,
        min: errors[0],
        median: errors[trials / 2],
        max: *errors.last().unwrap(),
        mean,
        rms,
    }
}

/// Measure one point: run `trials` independent streams of exactly
/// `cardinality` distinct values and collect relative errors (with the
/// default estimator).
pub fn measure_point(cfg: HllConfig, cardinality: u64, trials: usize) -> ErrorPoint {
    let (point, _) = measure_point_paired(cfg, cardinality, trials);
    point
}

/// As [`measure_point`], but evaluate *both* estimators on the same
/// sketches — identical streams, identical register files — so the
/// comparison isolates the computation phase from sampling noise.
/// Returns `(ertl, legacy)`.
pub fn measure_point_paired(
    cfg: HllConfig,
    cardinality: u64,
    trials: usize,
) -> (ErrorPoint, ErrorPoint) {
    let mut ertl_errors: Vec<f64> = Vec::with_capacity(trials);
    let mut legacy_errors: Vec<f64> = Vec::with_capacity(trials);
    let mut buf = vec![0u32; 65_536];
    for trial in 0..trials {
        let mut sketch = HllSketch::new(cfg);
        let seed = 0x9E3779B9u64 ^ ((trial as u64) << 32) ^ cardinality;
        let mut stream = DistinctStream::new(cardinality, seed);
        loop {
            let k = stream.fill(&mut buf);
            if k == 0 {
                break;
            }
            sketch.insert_batch(&buf[..k]);
        }
        let n = cardinality as f64;
        ertl_errors.push((sketch.estimate_with(EstimatorKind::Ertl) - n).abs() / n);
        legacy_errors.push((sketch.estimate_with(EstimatorKind::Legacy) - n).abs() / n);
    }
    (summarize(ertl_errors, cardinality), summarize(legacy_errors, cardinality))
}

/// Sweep a config over cardinalities (the Fig 1 x-axis).
pub fn sweep(cfg: HllConfig, cardinalities: &[u64], trials: usize) -> ErrorCurve {
    let points = cardinalities
        .iter()
        .map(|&n| {
            crate::log_debug!("stats", "profiling {:?} at n={}", cfg, n);
            measure_point(cfg, n, trials)
        })
        .collect();
    ErrorCurve { config: cfg, points }
}

/// The LinearCounting→HLL transition cardinality: 5/2 · m (the paper
/// locates the error bump at ≈ 40 k for p = 14).
pub fn transition_cardinality(cfg: &HllConfig) -> u64 {
    (2.5 * cfg.m() as f64) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hll::HashKind;

    #[test]
    fn log_spacing() {
        let cs = log_spaced_cardinalities(2, 4, 2);
        assert_eq!(cs.first(), Some(&100));
        assert_eq!(cs.last(), Some(&10_000));
        assert!(cs.windows(2).all(|w| w[1] > w[0]));
        // ~2 points per decade over 2 decades.
        assert_eq!(cs.len(), 5);
    }

    #[test]
    fn small_cardinality_linear_counting_is_tight() {
        let cfg = HllConfig::new(14, HashKind::H64).unwrap();
        let p = measure_point(cfg, 1_000, 5);
        assert!(p.median < 0.01, "LC should be near-exact: {p:?}");
    }

    #[test]
    fn mid_range_error_tracks_analytic_bound() {
        let cfg = HllConfig::new(12, HashKind::H64).unwrap(); // σ = 1.625%
        let p = measure_point(cfg, 500_000, 8);
        let sigma = cfg.standard_error();
        assert!(p.rms < 3.0 * sigma, "rms {} vs σ {}", p.rms, sigma);
        assert!(p.max < 6.0 * sigma, "max {} vs σ {}", p.max, sigma);
    }

    #[test]
    fn transition_location_p14() {
        let cfg = HllConfig::new(14, HashKind::H32).unwrap();
        // Paper: "the transition ... occurs at about 40k for p=14".
        assert_eq!(transition_cardinality(&cfg), 40_960);
    }

    #[test]
    fn h32_degrades_at_high_cardinality_h64_does_not() {
        // The core message of Fig 1, scaled down: run at p=12 with a
        // cardinality near 2^26 where a 32-bit hash's collision pressure
        // (n²/2^33 ≈ 0.5 %… visible) exceeds the 64-bit hash's.
        // Full-scale (10^8+) regeneration is `repro fig1 --full`.
        let n = 1 << 26;
        let cfg32 = HllConfig::new(12, HashKind::H32).unwrap();
        let cfg64 = HllConfig::new(12, HashKind::H64).unwrap();
        let e32 = measure_point(cfg32, n, 3);
        let e64 = measure_point(cfg64, n, 3);
        // 32-bit hash overestimates collisions → error grows; 64-bit
        // stays within ~3σ.
        assert!(
            e64.rms < 3.0 * cfg64.standard_error(),
            "H64 rms {} too large",
            e64.rms
        );
        assert!(e32.rms > e64.rms * 0.8, "expected H32 ≥ H64 error at n=2^26");
    }
}
