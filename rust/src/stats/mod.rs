//! Statistical profiling harness (Section IV / Fig 1): synthetic
//! distinct-value data sets and error-vs-cardinality sweeps.

pub mod datasets;
pub mod error_profile;

pub use datasets::{multiset_stream, DistinctStream};
pub use error_profile::{
    log_spaced_cardinalities, measure_point, measure_point_paired, sweep,
    transition_cardinality, ErrorCurve, ErrorPoint,
};
