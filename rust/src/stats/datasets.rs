//! Synthetic data sets for the profiling study (Section IV): streams of
//! exactly-n distinct 32-bit values "randomly sampling the range
//! [0 : 2^32 − 1]".
//!
//! Distinctness without a hash-set: a seeded *bijective* mixer over u32
//! (the Murmur3 finalizer, which is invertible) maps the counter
//! 0..n to n distinct pseudo-random values — O(1) memory at any n.

/// Murmur3's 32-bit finalizer — a bijection on u32.
#[inline]
fn mix32(mut x: u32) -> u32 {
    x ^= x >> 16;
    x = x.wrapping_mul(0x85EB_CA6B);
    x ^= x >> 13;
    x = x.wrapping_mul(0xC2B2_AE35);
    x ^= x >> 16;
    x
}

/// An iterator over exactly `n` distinct pseudo-random u32 values,
/// parameterized by trial seed (different seeds give different — though
/// possibly overlapping — value sets, as independent draws would).
#[derive(Debug, Clone)]
pub struct DistinctStream {
    i: u64,
    n: u64,
    seed: u32,
}

impl DistinctStream {
    pub fn new(n: u64, seed: u64) -> Self {
        assert!(n <= 1 << 32, "domain is 32-bit");
        // Fold the 64-bit trial seed into an xor mask; xor-pre/post of a
        // bijection stays bijective per seed.
        let seed = (seed ^ (seed >> 32)) as u32;
        Self { i: 0, n, seed }
    }

    pub fn remaining(&self) -> u64 {
        self.n - self.i
    }

    /// Fill `buf` with the next values; returns how many were produced.
    pub fn fill(&mut self, buf: &mut [u32]) -> usize {
        let take = (buf.len() as u64).min(self.remaining()) as usize;
        for slot in &mut buf[..take] {
            *slot = mix32(self.i as u32 ^ self.seed).wrapping_add(self.seed.rotate_left(7));
            self.i += 1;
        }
        take
    }
}

impl Iterator for DistinctStream {
    type Item = u32;

    fn next(&mut self) -> Option<u32> {
        if self.i >= self.n {
            return None;
        }
        let v = mix32(self.i as u32 ^ self.seed).wrapping_add(self.seed.rotate_left(7));
        self.i += 1;
        Some(v)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let r = self.remaining() as usize;
        (r, Some(r))
    }
}

/// A stream with duplicates: `n_distinct` values, each repeated per a
/// deterministic schedule, shuffled block-wise — exercises HLL's
/// duplicate insensitivity on realistic multisets.
pub fn multiset_stream(n_distinct: u64, repeat: u32, seed: u64) -> impl Iterator<Item = u32> {
    (0..repeat).flat_map(move |r| DistinctStream::new(n_distinct, seed).map(move |v| {
        let _ = r;
        v
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn values_are_distinct() {
        let n = 100_000u64;
        let mut seen = std::collections::HashSet::with_capacity(n as usize);
        for v in DistinctStream::new(n, 42) {
            assert!(seen.insert(v), "duplicate produced");
        }
        assert_eq!(seen.len() as u64, n);
    }

    #[test]
    fn seeds_give_different_sets() {
        let a: Vec<u32> = DistinctStream::new(1000, 1).collect();
        let b: Vec<u32> = DistinctStream::new(1000, 2).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn fill_matches_iterator() {
        let mut s1 = DistinctStream::new(10_000, 7);
        let it: Vec<u32> = DistinctStream::new(10_000, 7).collect();
        let mut buf = vec![0u32; 1024];
        let mut collected = Vec::new();
        loop {
            let k = s1.fill(&mut buf);
            if k == 0 {
                break;
            }
            collected.extend_from_slice(&buf[..k]);
        }
        assert_eq!(collected, it);
    }

    #[test]
    fn values_look_uniform() {
        // Bucket into 16 ranges; each should hold ~1/16 of the values.
        let n = 1 << 18;
        let mut counts = [0u32; 16];
        for v in DistinctStream::new(n, 3) {
            counts[(v >> 28) as usize] += 1;
        }
        let expect = n as f64 / 16.0;
        for (i, &c) in counts.iter().enumerate() {
            let dev = (c as f64 - expect).abs() / expect;
            assert!(dev < 0.05, "bucket {i}: {c} vs {expect}");
        }
    }

    #[test]
    fn multiset_cardinality_is_n_distinct() {
        let vals: Vec<u32> = multiset_stream(500, 4, 9).collect();
        assert_eq!(vals.len(), 2000);
        let set: std::collections::HashSet<u32> = vals.into_iter().collect();
        assert_eq!(set.len(), 500);
    }
}
