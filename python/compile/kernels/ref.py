"""Pure-NumPy correctness oracle for the Pallas kernels.

Implemented independently of the kernel code (NumPy uint arithmetic,
scalar-faithful port of the canonical MurmurHash3.cpp) so that agreement
between this oracle, the Pallas kernels, and the Rust implementation is a
three-way cross-check of the hash and rank logic.

Everything here is vectorized NumPy but deliberately *not* shared with
the jnp kernel implementations.
"""

from __future__ import annotations

import numpy as np

# --- MurmurHash3_x64_128 constants (Appleby, SMHasher) ---
_C1_64 = np.uint64(0x87C37B91114253D5)
_C2_64 = np.uint64(0x4CF5AA3D36495958)

# --- MurmurHash3_x86_32 constants ---
_C1_32 = np.uint32(0xCC9E2D51)
_C2_32 = np.uint32(0x1B873593)


def _rotl64(x: np.ndarray, r: int) -> np.ndarray:
    return (x << np.uint64(r)) | (x >> np.uint64(64 - r))


def _rotl32(x: np.ndarray, r: int) -> np.ndarray:
    return (x << np.uint32(r)) | (x >> np.uint32(32 - r))


def _fmix64(k: np.ndarray) -> np.ndarray:
    s33 = np.uint64(33)
    k = k ^ (k >> s33)
    k = k * np.uint64(0xFF51AFD7ED558CCD)
    k = k ^ (k >> s33)
    k = k * np.uint64(0xC4CEB9FE1A85EC53)
    k = k ^ (k >> s33)
    return k


def _fmix32(h: np.ndarray) -> np.ndarray:
    h = h ^ (h >> np.uint32(16))
    h = h * np.uint32(0x85EBCA6B)
    h = h ^ (h >> np.uint32(13))
    h = h * np.uint32(0xC2B2AE35)
    h = h ^ (h >> np.uint32(16))
    return h


def murmur3_x64_64_u32(keys: np.ndarray, seed: int = 0) -> np.ndarray:
    """Low 64 bits of MurmurHash3_x64_128 of each 4-byte LE u32 key.

    Mirrors the reference implementation's tail path for len == 4.
    """
    old = np.seterr(over="ignore")
    try:
        keys = np.asarray(keys, dtype=np.uint32)
        seed64 = np.uint64(seed)
        k1 = keys.astype(np.uint64)
        k1 = k1 * _C1_64
        k1 = _rotl64(k1, 31)
        k1 = k1 * _C2_64
        h1 = seed64 ^ k1
        h2 = np.full_like(h1, seed64)
        four = np.uint64(4)
        h1 = h1 ^ four
        h2 = h2 ^ four
        h1 = h1 + h2
        h2 = h2 + h1
        h1 = _fmix64(h1)
        h2 = _fmix64(h2)
        h1 = h1 + h2
        return h1
    finally:
        np.seterr(**old)


def murmur3_x86_32_u32(keys: np.ndarray, seed: int = 0) -> np.ndarray:
    """MurmurHash3_x86_32 of each 4-byte LE u32 key (one body block)."""
    old = np.seterr(over="ignore")
    try:
        keys = np.asarray(keys, dtype=np.uint32)
        k1 = keys * _C1_32
        k1 = _rotl32(k1, 15)
        k1 = k1 * _C2_32
        h1 = np.uint32(seed) ^ k1
        h1 = _rotl32(h1, 13)
        h1 = h1 * np.uint32(5) + np.uint32(0xE6546B64)
        h1 = h1 ^ np.uint32(4)  # length
        return _fmix32(h1)
    finally:
        np.seterr(**old)


def murmur3_x86_32_bytes(data: bytes, seed: int = 0) -> int:
    """Scalar byte-string variant — used to check published test vectors."""
    old = np.seterr(over="ignore")
    try:
        h1 = np.uint32(seed)
        nblocks = len(data) // 4
        for i in range(nblocks):
            k1 = np.uint32(int.from_bytes(data[i * 4 : i * 4 + 4], "little"))
            k1 = k1 * _C1_32
            k1 = _rotl32(k1, 15)
            k1 = k1 * _C2_32
            h1 = h1 ^ k1
            h1 = _rotl32(h1, 13)
            h1 = h1 * np.uint32(5) + np.uint32(0xE6546B64)
        tail = data[nblocks * 4 :]
        if tail:
            k1 = np.uint32(0)
            for i, b in enumerate(tail):
                k1 = k1 ^ np.uint32(b << (8 * i))
            k1 = k1 * _C1_32
            k1 = _rotl32(k1, 15)
            k1 = k1 * _C2_32
            h1 = h1 ^ k1
        h1 = h1 ^ np.uint32(len(data))
        return int(_fmix32(h1))
    finally:
        np.seterr(**old)


def index_and_rank(hashes: np.ndarray, p: int, h_bits: int):
    """Algorithm 1 lines 7-8: split an H-bit hash into (index, rank)."""
    hashes = np.asarray(hashes, dtype=np.uint64)
    w_bits = h_bits - p
    idx = (hashes >> np.uint64(w_bits)).astype(np.int64)
    w = hashes & np.uint64((1 << w_bits) - 1)
    # Rank = leading zeros within w_bits, +1; rank(0) = w_bits + 1.
    # Highest-set-bit via integer binary search (exact for all u64).
    rank = np.zeros(hashes.shape, dtype=np.int64)
    nz = w != 0
    wb = w[nz]
    hsb = np.zeros(wb.shape, dtype=np.int64)
    cur = wb.copy()
    for shift in (32, 16, 8, 4, 2, 1):
        step = cur >= (np.uint64(1) << np.uint64(shift))
        hsb = hsb + np.where(step, shift, 0)
        cur = np.where(step, cur >> np.uint64(shift), cur)
    rank[nz] = (w_bits - 1 - hsb) + 1
    rank[~nz] = w_bits + 1
    return idx, rank.astype(np.int32)


def hash_index_rank(keys: np.ndarray, p: int, h_bits: int, seed: int = 0):
    """The L1 kernel's contract: keys -> (index, rank)."""
    if h_bits == 64:
        hashes = murmur3_x64_64_u32(keys, seed)
    elif h_bits == 32:
        hashes = murmur3_x86_32_u32(keys, seed).astype(np.uint64)
    else:
        raise ValueError(f"unsupported hash width {h_bits}")
    return index_and_rank(hashes, p, h_bits)


def hll_aggregate(keys: np.ndarray, regs: np.ndarray, p: int, h_bits: int,
                  seed: int = 0) -> np.ndarray:
    """Full aggregation-phase oracle: scatter-max of ranks into registers."""
    idx, rank = hash_index_rank(keys, p, h_bits, seed)
    out = np.array(regs, dtype=np.int32, copy=True)
    np.maximum.at(out, idx, rank)
    return out


def hll_power_sum(regs: np.ndarray):
    """Computation-phase oracle: (sum 2^-M[j], V)."""
    regs = np.asarray(regs, dtype=np.int64)
    return float(np.exp2(-regs.astype(np.float64)).sum()), int((regs == 0).sum())


def hll_estimate(regs: np.ndarray, p: int, h_bits: int):
    """Algorithm 1 phase 4 oracle. Returns (raw, V, estimate)."""
    m = 1 << p
    regs = np.asarray(regs)
    assert regs.shape == (m,)
    s, v = hll_power_sum(regs)
    if m == 16:
        alpha = 0.673
    elif m == 32:
        alpha = 0.697
    elif m == 64:
        alpha = 0.709
    else:
        alpha = 0.7213 / (1.0 + 1.079 / m)
    raw = alpha * m * m / s
    if raw <= 2.5 * m:
        est = m * np.log(m / v) if v != 0 else raw
    elif h_bits == 32 and raw > (1 << 32) / 30.0:
        ratio = max(1.0 - raw / float(1 << 32), np.finfo(np.float64).tiny)
        est = -float(1 << 32) * np.log(ratio)
    else:
        est = raw
    return raw, v, float(est)
