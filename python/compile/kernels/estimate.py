"""Layer-1 Pallas kernel: the computation-phase register reduction.

The hardware's "Zero Counter and Bypass" + "Harmonic Mean" modules
(Fig. 2, stages 5-6) stream the bucket memory once, producing the power
sum Σ 2^−M[j] and the zero-register count V. Here the register file is
tiled through VMEM and reduced with per-grid-step accumulation — the
Pallas analogue of the FPGA's single-pass drain (whose 2^p-cycle latency
the L3 simulator models as the paper's 203 µs constant).
"""

from __future__ import annotations

import functools

from . import _x64  # noqa: F401

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 4096


def _kernel(regs_ref, sum_ref, zeros_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        zeros_ref[...] = jnp.zeros_like(zeros_ref)

    r = regs_ref[...]
    # Each addend 2^-M[j] is exact in f64 (a single mantissa bit); the
    # accumulated sum is exact to f64 rounding — the wide fixed-point
    # accumulator of the hardware is modelled bit-exactly on the Rust
    # side, and estimates agree to < 1e-12 relative (asserted in tests).
    sum_ref[...] += jnp.sum(jnp.exp2(-r.astype(jnp.float64)), keepdims=True)
    # Pin the accumulator dtype: with jax_enable_x64 the default sum
    # dtype widens to int64, which the i32 output ref rejects.
    zeros_ref[...] += jnp.sum(r == 0, dtype=jnp.int32, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block",))
def power_sum(regs_i32, *, block=DEFAULT_BLOCK):
    """Σ 2^−M[j] (f64[1]) and zero count V (i32[1]) over the registers."""
    (m,) = regs_i32.shape
    block = min(block, m)
    if m % block != 0:
        raise ValueError(f"register count {m} not a multiple of block {block}")
    grid = m // block
    return pl.pallas_call(
        _kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), jnp.float64),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=True,
    )(regs_i32)
