"""Layer-1 Pallas kernel: the paper's compute hot-spot.

One fused kernel computes, for a tile of 32-bit stream words, the three
front-of-pipeline stages of Fig. 2:

    Murmur3 hash  →  index extractor  →  leading-zero detector

returning `(bucket_index, rank)` per word. The bucket scatter-max (the
BRAM "Buckets" stage) is expressed at Layer 2 where XLA's scatter op
implements it; see `python/compile/model.py`.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the FPGA unrolls
the hash's multiply/rotate chain *spatially* across DSP slices at II=1;
on TPU the same insight maps to *batch vectorization* — each grid step
streams one VMEM-resident tile of words through the VPU's integer lanes.
BlockSpec expresses the HBM↔VMEM schedule that the FPGA's AXI4 stream +
BRAM plumbing provides.

Kernels are lowered with `interpret=True`: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode lowers to portable HLO.
"""

from __future__ import annotations

import functools

from . import _x64  # noqa: F401  (enables jax_enable_x64)

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

# MurmurHash3_x64_128 constants (Appleby, SMHasher).
_C1_64 = 0x87C37B91114253D5
_C2_64 = 0x4CF5AA3D36495958
# MurmurHash3_x86_32 constants.
_C1_32 = 0xCC9E2D51
_C2_32 = 0x1B873593

# Tile size for the BlockSpec HBM↔VMEM schedule. 8192 words/tile keeps
# the live set ≈ 0.4 MiB (u32 keys + u64 hash chain intermediates, ~48 B
# per element) — comfortably inside a TPU core's ~16 MiB VMEM — while
# minimizing grid-step dispatch overhead; measured 1.9× over 1024-word
# tiles on the CPU interpret path (EXPERIMENTS.md §Perf).
DEFAULT_BLOCK = 8192


def _rotl(x, r, bits):
    sh_l = jnp.array(r, dtype=x.dtype)
    sh_r = jnp.array(bits - r, dtype=x.dtype)
    return (x << sh_l) | (x >> sh_r)


def _fmix64(k):
    s = jnp.array(33, dtype=jnp.uint64)
    k = k ^ (k >> s)
    k = k * jnp.uint64(0xFF51AFD7ED558CCD)
    k = k ^ (k >> s)
    k = k * jnp.uint64(0xC4CEB9FE1A85EC53)
    k = k ^ (k >> s)
    return k


def _fmix32(h):
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> jnp.uint32(13))
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> jnp.uint32(16))
    return h


def murmur3_x64_64_u32(keys_u32):
    """Vectorized 64-bit Murmur3 (low half of x64_128) of u32 keys.

    Matches the canonical byte-string implementation's tail path for
    4-byte inputs with seed 0 (the seed all layers agree on).
    """
    k1 = keys_u32.astype(jnp.uint64)
    k1 = k1 * jnp.uint64(_C1_64)
    k1 = _rotl(k1, 31, 64)
    k1 = k1 * jnp.uint64(_C2_64)
    h1 = k1  # seed(0) ^ k1
    h2 = jnp.zeros_like(h1)
    four = jnp.uint64(4)
    h1 = h1 ^ four
    h2 = h2 ^ four
    h1 = h1 + h2
    h2 = h2 + h1
    h1 = _fmix64(h1)
    h2 = _fmix64(h2)
    return h1 + h2


def murmur3_x86_32_u32(keys_u32):
    """Vectorized MurmurHash3_x86_32 of u32 keys (one body block, seed 0)."""
    k1 = keys_u32 * jnp.uint32(_C1_32)
    k1 = _rotl(k1, 15, 32)
    k1 = k1 * jnp.uint32(_C2_32)
    h1 = k1  # seed(0) ^ k1
    h1 = _rotl(h1, 13, 32)
    h1 = h1 * jnp.uint32(5) + jnp.uint32(0xE6546B64)
    h1 = h1 ^ jnp.uint32(4)
    return _fmix32(h1)


def _leading_zeros(w, w_bits):
    """Leading zeros of `w` within a `w_bits`-wide word; exact via
    bit-smear + population count (the VPU analogue of the FPGA's LZD /
    x86's LZCNT)."""
    x = w
    shift = 1
    while shift < w_bits:
        x = x | (x >> jnp.array(shift, dtype=x.dtype))
        shift *= 2
    return jnp.array(w_bits, jnp.int32) - lax.population_count(x).astype(jnp.int32)


def _index_rank_block(keys_u32, p, h_bits):
    """(index, rank) for one tile — shared by the kernel body and tests."""
    if h_bits == 64:
        h = murmur3_x64_64_u32(keys_u32)
        w_bits = 64 - p
        idx = (h >> jnp.uint64(w_bits)).astype(jnp.int32)
        w = h & jnp.uint64((1 << w_bits) - 1)
    elif h_bits == 32:
        h = murmur3_x86_32_u32(keys_u32)
        w_bits = 32 - p
        idx = (h >> jnp.uint32(w_bits)).astype(jnp.int32)
        w = h & jnp.uint32((1 << w_bits) - 1)
    else:
        raise ValueError(f"unsupported hash width {h_bits}")
    rank = _leading_zeros(w, w_bits) + 1
    return idx, rank


def _kernel(keys_ref, idx_ref, rank_ref, *, p, h_bits):
    keys = keys_ref[...]
    idx, rank = _index_rank_block(keys, p, h_bits)
    idx_ref[...] = idx
    rank_ref[...] = rank


@functools.partial(jax.jit, static_argnames=("p", "h_bits", "block"))
def hash_index_rank(keys_u32, *, p, h_bits, block=DEFAULT_BLOCK):
    """Pallas-tiled hash + index-extract + rank over a batch of u32 keys.

    `keys_u32.shape[0]` must be a multiple of `block` (the coordinator
    always feeds full batches; odd tails are handled on the Rust side).
    Returns `(idx int32[B], rank int32[B])`.
    """
    (n,) = keys_u32.shape
    block = min(block, n)
    if n % block != 0:
        raise ValueError(f"batch {n} not a multiple of block {block}")
    grid = n // block
    return pl.pallas_call(
        functools.partial(_kernel, p=p, h_bits=h_bits),
        grid=(grid,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=True,
    )(keys_u32)
