"""Enable 64-bit mode before any jax import users touch arrays.

The 64-bit Murmur3 path needs uint64 arithmetic; every module in the
compile package imports this first.
"""

import jax

jax.config.update("jax_enable_x64", True)
