"""AOT export: lower the Layer-2 model to HLO text for the Rust runtime.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the `xla`
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Produces, under --out (default ../artifacts):

* one `<name>.hlo.txt` per model variant;
* `manifest.tsv` — the machine-readable index the Rust loader parses
  (columns: name, file, kind, p, h_bits, batch, m, outputs);
* `manifest.json` — the same, for humans and tooling.

Python runs ONCE at build time (`make artifacts`); the Rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import os

from .kernels import _x64  # noqa: F401

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# The paper's hardware configuration is (p=16, H=64); additional variants
# cover the profiling study (p=14, H=32) and multiple batch sizes for the
# coordinator's batching policy.
AGGREGATE_VARIANTS = [
    # (p, h_bits, batch)
    (16, 64, 8192),
    (16, 64, 65536),
    (16, 64, 1024),
    (16, 32, 8192),
    (14, 64, 8192),
]
ESTIMATE_VARIANTS = [(16, 64), (16, 32), (14, 64)]
MERGE_VARIANTS = [16, 14]
FUSED_VARIANTS = [(16, 64, 8192)]


def to_hlo_text(lowered, return_tuple: bool = False) -> str:
    """StableHLO → XlaComputation → HLO text (id-reassigning path).

    `return_tuple=False` for single-output modules lets the Rust runtime
    keep results as plain device buffers (no tuple unwrap → the register
    file can stay device-resident across chunked aggregate calls, the
    donated-buffer analogue measured in EXPERIMENTS.md §Perf).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def build_entries():
    """Yield (name, lowered, meta) for every artifact."""
    for p, h, b in AGGREGATE_VARIANTS:
        m = 1 << p
        name = f"aggregate_p{p}_h{h}_b{b}"
        lowered = jax.jit(
            lambda keys, regs, p=p, h=h: model.hll_aggregate(
                keys, regs, p=p, h_bits=h
            )
        ).lower(_i32(b), _i32(m))
        yield name, lowered, dict(kind="aggregate", p=p, h_bits=h, batch=b,
                                  m=m, outputs="regs:i32[m]")

    for p, h in ESTIMATE_VARIANTS:
        m = 1 << p
        name = f"estimate_p{p}_h{h}"
        lowered = jax.jit(
            lambda regs, p=p, h=h: model.hll_estimate(regs, p=p, h_bits=h)
        ).lower(_i32(m))
        yield name, lowered, dict(kind="estimate", p=p, h_bits=h, batch=0,
                                  m=m, outputs="stats:f64[3]")

    for p in MERGE_VARIANTS:
        m = 1 << p
        name = f"merge_p{p}"
        lowered = jax.jit(model.hll_merge).lower(_i32(m), _i32(m))
        yield name, lowered, dict(kind="merge", p=p, h_bits=0, batch=0,
                                  m=m, outputs="regs:i32[m]")

    for p, h, b in FUSED_VARIANTS:
        m = 1 << p
        name = f"aggregate_estimate_p{p}_h{h}_b{b}"
        lowered = jax.jit(
            lambda keys, regs, p=p, h=h: model.hll_aggregate_and_estimate(
                keys, regs, p=p, h_bits=h
            )
        ).lower(_i32(b), _i32(m))
        yield name, lowered, dict(kind="aggregate_estimate", p=p, h_bits=h,
                                  batch=b, m=m,
                                  outputs="regs:i32[m],stats:f64[3]")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="output directory for artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = []
    for name, lowered, meta in build_entries():
        multi_output = meta["kind"] == "aggregate_estimate"
        text = to_hlo_text(lowered, return_tuple=multi_output)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out, fname)
        with open(path, "w") as f:
            f.write(text)
        entry = dict(name=name, file=fname, **meta)
        manifest.append(entry)
        print(f"  wrote {fname:<44} ({len(text) / 1024:8.1f} KiB)")

    # TSV for the dependency-free Rust loader.
    cols = ["name", "file", "kind", "p", "h_bits", "batch", "m", "outputs"]
    with open(os.path.join(args.out, "manifest.tsv"), "w") as f:
        f.write("\t".join(cols) + "\n")
        for e in manifest:
            f.write("\t".join(str(e[c]) for c in cols) + "\n")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest)} entries to {args.out}")


if __name__ == "__main__":
    main()
