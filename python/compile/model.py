"""Layer-2 JAX model: the HLL compute graph, calling the Layer-1 kernels.

Three entry points, mirroring the hardware architecture:

* :func:`hll_aggregate` — the aggregation phase (Fig. 2's pipeline up to
  and including the BRAM bucket update): a batch of 32-bit words updates
  the register file. The hash/index/rank front-end is the Pallas kernel;
  the bucket update is an XLA scatter-max.
* :func:`hll_estimate` — the computation phase: power-sum reduction
  (Pallas kernel) plus Algorithm 1's correction branches, fully
  branch-free so it lowers to a single straight-line HLO module.
* :func:`hll_merge` — bucket-wise max, the parallel architecture's
  "Merge buckets" fold (Fig. 3).

All functions are pure and jit-lowerable; `aot.py` exports them as HLO
text for the Rust runtime. The Rust side passes i32 buffers (the `xla`
crate's ergonomic type) and bit-level reinterpretation happens here.
"""

from __future__ import annotations

import functools

from .kernels import _x64  # noqa: F401

import jax
import jax.numpy as jnp

from .kernels import estimate as estimate_kernel
from .kernels import murmur3 as murmur3_kernel


def _alpha(m: int) -> float:
    if m == 16:
        return 0.673
    if m == 32:
        return 0.697
    if m == 64:
        return 0.709
    return 0.7213 / (1.0 + 1.079 / m)


@functools.partial(jax.jit, static_argnames=("p", "h_bits", "block"))
def hll_aggregate(keys_i32, regs_i32, *, p, h_bits,
                  block=murmur3_kernel.DEFAULT_BLOCK):
    """Aggregation phase: fold a batch of 32-bit words into the registers.

    `keys_i32` carries the raw stream words as i32 bit patterns (the
    Rust↔PJRT interchange type); they are bitcast to u32 here.
    """
    keys_u32 = jax.lax.bitcast_convert_type(keys_i32, jnp.uint32)
    idx, rank = murmur3_kernel.hash_index_rank(keys_u32, p=p, h_bits=h_bits,
                                               block=block)
    # The "Buckets" stage: M[idx] = max(M[idx], rank). XLA scatter-max
    # merges in-batch duplicates exactly like the hardware merges updates
    # that collide during the BRAM read-modify-write window.
    return regs_i32.at[idx].max(rank, indices_are_sorted=False,
                                unique_indices=False)


@functools.partial(jax.jit, static_argnames=("p", "h_bits", "block"))
def hll_estimate(regs_i32, *, p, h_bits,
                 block=estimate_kernel.DEFAULT_BLOCK):
    """Computation phase: registers → f64[3] = (raw E, V, estimate E*).

    Branch-free port of Algorithm 1 lines 11-23.
    """
    m = 1 << p
    if regs_i32.shape != (m,):
        raise ValueError(f"expected {m} registers, got {regs_i32.shape}")
    psum, zeros = estimate_kernel.power_sum(regs_i32, block=block)
    s = psum[0]
    v = zeros[0]
    raw = _alpha(m) * m * m / s

    v_f = v.astype(jnp.float64)
    # LinearCounting(m, V) = m·ln(m/V); V clamped to keep the log finite
    # on the not-taken branch.
    lc = m * jnp.log(m / jnp.maximum(v_f, 1.0))
    use_lc = (raw <= 2.5 * m) & (v > 0)

    if h_bits == 32:
        two32 = float(1 << 32)
        ratio = jnp.maximum(1.0 - raw / two32, jnp.finfo(jnp.float64).tiny)
        lr = -two32 * jnp.log(ratio)
        use_lr = raw > two32 / 30.0
        est = jnp.where(use_lc, lc, jnp.where(use_lr, lr, raw))
    else:
        # 64-bit hash: large-range correction is obsolete (Section III).
        est = jnp.where(use_lc, lc, raw)

    return jnp.stack([raw, v_f, est])


@jax.jit
def hll_merge(regs_a_i32, regs_b_i32):
    """Bucket-wise max fold (Fig. 3 "Merge buckets")."""
    return jnp.maximum(regs_a_i32, regs_b_i32)


@functools.partial(jax.jit, static_argnames=("p", "h_bits", "block"))
def hll_aggregate_and_estimate(keys_i32, regs_i32, *, p, h_bits,
                               block=murmur3_kernel.DEFAULT_BLOCK):
    """Fused variant: one round trip for aggregate + estimate — used by
    the coordinator when a batch closes a stream (saves one PJRT call)."""
    regs = hll_aggregate(keys_i32, regs_i32, p=p, h_bits=h_bits, block=block)
    stats = hll_estimate(regs, p=p, h_bits=h_bits)
    return regs, stats
