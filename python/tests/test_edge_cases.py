"""Edge cases of the compile-path numerics: extreme keys, saturated
registers, the exact LC/HLL threshold, and dtype discipline."""

import numpy as np

import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def test_extreme_keys():
    """Keys at the domain edges hash and rank like the oracle."""
    keys = np.array(
        [0, 1, 2, 0x7FFFFFFF, 0x80000000, 0xFFFFFFFE, 0xFFFFFFFF] + [0] * 57,
        dtype=np.uint32,
    )
    for p, h in [(16, 64), (14, 32), (4, 64)]:
        idx_r, rank_r = ref.hash_index_rank(keys, p, h)
        regs = np.zeros(1 << p, dtype=np.int32)
        out = np.asarray(model.hll_aggregate(
            jnp.asarray(keys.view(np.int32)), jnp.asarray(regs),
            p=p, h_bits=h, block=64))
        expect = ref.hll_aggregate(keys, regs, p, h)
        np.testing.assert_array_equal(out, expect)
        assert rank_r.max() <= h - p + 1
        assert idx_r.max() < (1 << p)


def test_saturated_registers_estimate_finite():
    """All registers at max rank: the estimate must stay finite (the
    large-range-correction clamp for H=32)."""
    for p, h in [(16, 64), (16, 32), (14, 32)]:
        m = 1 << p
        regs = np.full(m, h - p + 1, dtype=np.int32)
        stats = np.asarray(model.hll_estimate(jnp.asarray(regs), p=p, h_bits=h))
        assert np.isfinite(stats).all(), (p, h, stats)
        assert stats[2] > 0


def test_lc_threshold_branch_is_exact():
    """Register files straddling E = 5/2·m must pick the same branch as
    the oracle (the correction mux of Fig 2)."""
    p, h = 12, 64
    m = 1 << p
    rng = np.random.default_rng(0)
    for fill in (0.05, 0.3, 0.6, 0.95):
        regs = np.zeros(m, dtype=np.int32)
        k = int(m * fill)
        regs[rng.choice(m, size=k, replace=False)] = rng.integers(1, 20, size=k)
        raw_r, v_r, est_r = ref.hll_estimate(regs, p, h)
        stats = np.asarray(model.hll_estimate(jnp.asarray(regs), p=p, h_bits=h))
        np.testing.assert_allclose(stats[2], est_r, rtol=1e-12, err_msg=str(fill))


def test_aggregate_preserves_dtype_and_shape():
    keys = np.zeros(1024, dtype=np.int32)
    regs = np.zeros(1 << 14, dtype=np.int32)
    out = model.hll_aggregate(jnp.asarray(keys), jnp.asarray(regs), p=14,
                              h_bits=64)
    assert out.shape == (1 << 14,)
    assert out.dtype == jnp.int32


def test_merge_idempotent_and_commutative():
    rng = np.random.default_rng(3)
    a = rng.integers(0, 49, size=1 << 16).astype(np.int32)
    b = rng.integers(0, 49, size=1 << 16).astype(np.int32)
    ab = np.asarray(model.hll_merge(jnp.asarray(a), jnp.asarray(b)))
    ba = np.asarray(model.hll_merge(jnp.asarray(b), jnp.asarray(a)))
    aa = np.asarray(model.hll_merge(jnp.asarray(a), jnp.asarray(a)))
    np.testing.assert_array_equal(ab, ba)
    np.testing.assert_array_equal(aa, a)


def test_all_same_key_fills_exactly_one_register():
    keys = np.full(1024, 0xDEADBEEF, dtype=np.uint32)
    regs = np.zeros(1 << 16, dtype=np.int32)
    out = np.asarray(model.hll_aggregate(
        jnp.asarray(keys.view(np.int32)), jnp.asarray(regs), p=16, h_bits=64))
    assert (out > 0).sum() == 1
