"""Layer-2 correctness: the JAX model graph vs the NumPy oracle, plus the
algebraic properties (merge semilattice, fusion consistency) the
coordinator relies on."""

import numpy as np
import pytest

# The offline image may lack hypothesis; skip the fuzzed suites
# cleanly instead of failing collection.
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels import ref

settings.register_profile("model", deadline=None, max_examples=15)
settings.load_profile("model")


def _keys(n, seed=0):
    return np.random.default_rng(seed).integers(0, 2**32, size=n,
                                                dtype=np.uint32)


@given(seed=st.integers(0, 2**31), p=st.sampled_from([8, 14, 16]),
       h_bits=st.sampled_from([32, 64]))
def test_aggregate_matches_ref(seed, p, h_bits):
    keys = _keys(1024, seed)
    m = 1 << p
    regs = np.zeros(m, dtype=np.int32)
    out_ref = ref.hll_aggregate(keys, regs, p, h_bits)
    out_mod = np.asarray(model.hll_aggregate(
        jnp.asarray(keys.view(np.int32)), jnp.asarray(regs),
        p=p, h_bits=h_bits))
    np.testing.assert_array_equal(out_ref, out_mod)


@given(seed=st.integers(0, 2**31))
def test_aggregate_accumulates_onto_existing_registers(seed):
    """Aggregation must max into the provided registers, not overwrite."""
    keys = _keys(1024, seed)
    m = 1 << 14
    rng = np.random.default_rng(seed ^ 0xABCDEF)
    regs0 = rng.integers(0, 51, size=m).astype(np.int32)
    out_ref = ref.hll_aggregate(keys, regs0, 14, 64)
    out_mod = np.asarray(model.hll_aggregate(
        jnp.asarray(keys.view(np.int32)), jnp.asarray(regs0),
        p=14, h_bits=64))
    np.testing.assert_array_equal(out_ref, out_mod)
    assert (out_mod >= regs0).all()


@given(seed=st.integers(0, 2**31), p=st.sampled_from([8, 16]),
       h_bits=st.sampled_from([32, 64]))
def test_estimate_matches_ref(seed, p, h_bits):
    m = 1 << p
    max_rank = h_bits - p + 1
    rng = np.random.default_rng(seed)
    # Mix zero-heavy and saturated register files to hit all branches.
    mode = seed % 3
    if mode == 0:
        regs = np.zeros(m, dtype=np.int32)
        k = rng.integers(0, m)
        regs[rng.choice(m, size=k, replace=False)] = rng.integers(
            1, max_rank + 1, size=k)
    elif mode == 1:
        regs = rng.integers(0, max_rank + 1, size=m).astype(np.int32)
    else:
        regs = np.full(m, max_rank, dtype=np.int32)
    raw_r, v_r, est_r = ref.hll_estimate(regs, p, h_bits)
    stats = np.asarray(model.hll_estimate(jnp.asarray(regs), p=p,
                                          h_bits=h_bits))
    np.testing.assert_allclose(stats[0], raw_r, rtol=1e-12)
    assert int(stats[1]) == v_r
    np.testing.assert_allclose(stats[2], est_r, rtol=1e-12)


def test_merge_is_elementwise_max():
    rng = np.random.default_rng(5)
    a = rng.integers(0, 49, size=1 << 16).astype(np.int32)
    b = rng.integers(0, 49, size=1 << 16).astype(np.int32)
    out = np.asarray(model.hll_merge(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(out, np.maximum(a, b))


def test_merge_equals_concatenated_stream():
    """Fig 3's correctness property: slicing + merge == single pipeline."""
    keys = _keys(8192, 42)
    m = 1 << 16
    zeros = np.zeros(m, dtype=np.int32)
    halves = [keys[:4096], keys[4096:]]
    parts = [
        np.asarray(model.hll_aggregate(jnp.asarray(h.view(np.int32)),
                                       jnp.asarray(zeros), p=16, h_bits=64))
        for h in halves
    ]
    merged = np.asarray(model.hll_merge(jnp.asarray(parts[0]),
                                        jnp.asarray(parts[1])))
    whole = np.asarray(model.hll_aggregate(jnp.asarray(keys.view(np.int32)),
                                           jnp.asarray(zeros),
                                           p=16, h_bits=64))
    np.testing.assert_array_equal(merged, whole)


def test_fused_aggregate_estimate_consistent():
    keys = _keys(8192, 9)
    m = 1 << 16
    regs = np.zeros(m, dtype=np.int32)
    regs_f, stats_f = model.hll_aggregate_and_estimate(
        jnp.asarray(keys.view(np.int32)), jnp.asarray(regs), p=16, h_bits=64)
    regs_sep = model.hll_aggregate(jnp.asarray(keys.view(np.int32)),
                                   jnp.asarray(regs), p=16, h_bits=64)
    stats_sep = model.hll_estimate(regs_sep, p=16, h_bits=64)
    np.testing.assert_array_equal(np.asarray(regs_f), np.asarray(regs_sep))
    np.testing.assert_allclose(np.asarray(stats_f), np.asarray(stats_sep),
                               rtol=1e-15)


def test_estimate_accuracy_end_to_end():
    """Sanity: ~50k distinct keys at p=16/H=64 estimate within 2%."""
    n = 51_200  # 50 blocks of 1024
    keys = np.arange(n, dtype=np.uint32) * np.uint32(2654435761)
    m = 1 << 16
    regs = model.hll_aggregate(jnp.asarray(keys.view(np.int32)),
                               jnp.asarray(np.zeros(m, dtype=np.int32)),
                               p=16, h_bits=64, block=1024)
    stats = np.asarray(model.hll_estimate(regs, p=16, h_bits=64))
    est = stats[2]
    assert abs(est - n) / n < 0.02, est
