"""Cross-language estimator parity: python oracle vs rust Legacy path.

The rust crate's ``EstimatorKind::Legacy`` must compute *exactly* the
estimator that ``ref.hll_estimate`` (and the Pallas estimate kernel)
implement — the rust engine-parity test pins the native backend to it,
so a silent divergence here would split the serving layer from the
compiled artifacts.

Both languages synthesize identical register files from a shared
splitmix64 generator and check the same committed golden estimates
(``rust/tests/estimator_parity.rs`` is the twin). The goldens cover all
three legacy branches: LinearCounting, raw, and the 32-bit large-range
correction, plus a small-m alpha-table config.
"""

import numpy as np
import pytest

from compile.kernels import ref

_M64 = (1 << 64) - 1


def _splitmix(state):
    """One splitmix64 step; returns (new_state, output)."""
    state = (state + 0x9E3779B97F4A7C15) & _M64
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _M64
    z = z ^ (z >> 31)
    return state, z


def synth_registers(p, h_bits, seed, occ_per_mille, rank_offset):
    """Deterministic register file: per register draw (occupied?, rank).

    Mirrored line-for-line in the rust twin; any drift in the sequence
    shows up as a golden mismatch on both sides.
    """
    m = 1 << p
    max_rank = h_bits - p + 1
    state = seed
    regs = np.zeros(m, dtype=np.int32)
    for j in range(m):
        state, x = _splitmix(state)
        state, y = _splitmix(state)
        if x % 1000 < occ_per_mille:
            tz = 64 if y == 0 else (y & -y).bit_length() - 1
            regs[j] = min(rank_offset + 1 + tz, max_rank)
    return regs


# (p, h_bits, seed, occ_per_mille, rank_offset, expected_estimate, branch)
GOLDEN = [
    (12, 64, 0xA5A5, 1000, 0, 8897.226585133449, "raw"),
    (12, 64, 0x1234, 120, 0, 566.4193796524122, "LC"),
    (14, 64, 0xBEEF, 500, 0, 11618.608482912226, "LC"),
    (12, 32, 0xCAFE, 1000, 14, 146845837.76433104, "LR"),
    (16, 64, 0x42, 1000, 0, 141701.6198943316, "raw"),
    (4, 32, 0x7, 1000, 0, 32.622579881656804, "raw"),
]


@pytest.mark.parametrize("p,h_bits,seed,occ,off,expected,branch",
                         GOLDEN, ids=[g[6] + f"-p{g[0]}" for g in GOLDEN])
def test_oracle_matches_goldens(p, h_bits, seed, occ, off, expected, branch):
    regs = synth_registers(p, h_bits, seed, occ, off)
    raw, v, est = ref.hll_estimate(regs, p, h_bits)
    # Confirm each case still exercises the branch it was designed for.
    m = 1 << p
    if branch == "LC":
        assert raw <= 2.5 * m and v != 0
    elif branch == "LR":
        assert h_bits == 32 and raw > (1 << 32) / 30.0
    else:
        assert raw > 2.5 * m or v == 0
        assert not (h_bits == 32 and raw > (1 << 32) / 30.0)
    np.testing.assert_allclose(est, expected, rtol=1e-12)


def test_model_estimate_matches_goldens():
    """The JAX model graph agrees with the committed constants too."""
    pytest.importorskip("jax")
    import jax.numpy as jnp
    from compile import model

    for p, h_bits, seed, occ, off, expected, _branch in GOLDEN:
        regs = synth_registers(p, h_bits, seed, occ, off)
        out = np.asarray(model.hll_estimate(jnp.asarray(regs), p=p,
                                            h_bits=h_bits))
        # f64[3] = (raw, V, estimate); kernel reductions may reassociate,
        # so the tolerance is looser than the oracle's.
        np.testing.assert_allclose(out[2], expected, rtol=1e-9)
