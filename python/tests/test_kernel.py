"""Layer-1 correctness: Pallas kernels vs the pure-NumPy oracle.

This is the CORE correctness signal for the compile path — hypothesis
sweeps shapes, precisions and hash widths, asserting exact agreement
(integer outputs, so allclose == array_equal).
"""

import numpy as np
import pytest
# The offline image may lack hypothesis; skip the fuzzed suites
# cleanly instead of failing collection.
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import estimate as estimate_kernel
from compile.kernels import murmur3 as murmur3_kernel
from compile.kernels import ref

# Keep hypothesis deadlines off: pallas interpret mode has per-shape
# compile overhead on first run.
settings.register_profile("kernels", deadline=None, max_examples=25)
settings.load_profile("kernels")


KEYS = st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=512)


def _pad_to_block(keys, block):
    n = len(keys)
    padded = n if n % block == 0 else (n // block + 1) * block
    return np.asarray(keys + [0] * (padded - n), dtype=np.uint32)


@given(keys=KEYS, p=st.sampled_from([4, 8, 12, 14, 16]),
       h_bits=st.sampled_from([32, 64]),
       block=st.sampled_from([64, 256, 1024]))
def test_hash_index_rank_matches_ref(keys, p, h_bits, block):
    arr = _pad_to_block(keys, block)
    idx_r, rank_r = ref.hash_index_rank(arr, p, h_bits)
    idx_k, rank_k = murmur3_kernel.hash_index_rank(
        jnp.asarray(arr), p=p, h_bits=h_bits, block=block)
    np.testing.assert_array_equal(idx_r, np.asarray(idx_k))
    np.testing.assert_array_equal(rank_r, np.asarray(rank_k))


@given(keys=KEYS)
def test_murmur3_x64_64_matches_ref(keys, ):
    arr = _pad_to_block(keys, 64)
    h_ref = ref.murmur3_x64_64_u32(arr)
    h_jnp = np.asarray(murmur3_kernel.murmur3_x64_64_u32(jnp.asarray(arr)))
    np.testing.assert_array_equal(h_ref, h_jnp)


@given(keys=KEYS)
def test_murmur3_x86_32_matches_ref(keys):
    arr = _pad_to_block(keys, 64)
    h_ref = ref.murmur3_x86_32_u32(arr)
    h_jnp = np.asarray(murmur3_kernel.murmur3_x86_32_u32(jnp.asarray(arr)))
    np.testing.assert_array_equal(h_ref, h_jnp)


def test_block_size_invariance():
    """Tiling must not change results (BlockSpec schedule is pure)."""
    rng = np.random.default_rng(7)
    keys = rng.integers(0, 2**32, size=4096, dtype=np.uint32)
    base = None
    for block in (64, 128, 512, 1024, 4096):
        idx, rank = murmur3_kernel.hash_index_rank(
            jnp.asarray(keys), p=16, h_bits=64, block=block)
        cur = (np.asarray(idx), np.asarray(rank))
        if base is None:
            base = cur
        else:
            np.testing.assert_array_equal(base[0], cur[0])
            np.testing.assert_array_equal(base[1], cur[1])


def test_non_divisible_block_rejected():
    keys = jnp.zeros(100, dtype=jnp.uint32)
    with pytest.raises(ValueError, match="not a multiple"):
        murmur3_kernel.hash_index_rank(keys, p=16, h_bits=64, block=64)


def test_published_x86_32_vectors():
    """Canonical SMHasher/Wikipedia test vectors for the scalar path."""
    cases = [
        (b"", 0, 0x00000000),
        (b"", 1, 0x514E28B7),
        (b"", 0xFFFFFFFF, 0x81F16F39),
        (bytes([0xFF, 0xFF, 0xFF, 0xFF]), 0, 0x76293B50),
        (bytes([0x21, 0x43, 0x65, 0x87]), 0, 0xF55B516B),
        (bytes([0x21, 0x43, 0x65, 0x87]), 0x5082EDEE, 0x2362F9DE),
        (bytes([0x21, 0x43, 0x65]), 0, 0x7E4A8634),
        (bytes([0x21, 0x43]), 0, 0xA0F7B07A),
        (bytes([0x21]), 0, 0x72661CF4),
        (bytes([0, 0, 0, 0]), 0, 0x2362F9DE),
    ]
    for data, seed, expect in cases:
        assert ref.murmur3_x86_32_bytes(data, seed) == expect, data


def test_vectorized_x86_32_matches_scalar_bytes():
    """The u32 fast path must agree with the byte-string reference."""
    rng = np.random.default_rng(3)
    keys = rng.integers(0, 2**32, size=64, dtype=np.uint32)
    vec = ref.murmur3_x86_32_u32(keys)
    for k, h in zip(keys, vec):
        assert ref.murmur3_x86_32_bytes(int(k).to_bytes(4, "little")) == int(h)


def test_rank_bounds():
    """Ranks are in [1, H-p+1] (paper eq. (2)) for adversarial keys."""
    keys = np.array([0, 1, 2**31, 2**32 - 1, 0x8000, 0xFFFF], dtype=np.uint32)
    for p in (4, 16):
        for h_bits in (32, 64):
            _, rank = ref.hash_index_rank(keys, p, h_bits)
            assert rank.min() >= 1
            assert rank.max() <= h_bits - p + 1


def test_rank_distribution_geometric():
    """P(rank ≥ k) ≈ 2^-(k-1): the geometric tail HLL relies on."""
    rng = np.random.default_rng(11)
    keys = rng.integers(0, 2**32, size=1 << 16, dtype=np.uint32)
    _, rank = ref.hash_index_rank(keys, 4, 64)
    n = len(rank)
    for k in (1, 2, 3, 4, 5):
        frac = (rank >= k).mean()
        expect = 2.0 ** -(k - 1)
        assert abs(frac - expect) < 0.02, (k, frac, expect)


@given(regs=st.lists(st.integers(0, 49), min_size=64, max_size=64))
def test_power_sum_matches_ref(regs):
    arr = np.asarray(regs, dtype=np.int32)
    s_ref, v_ref = ref.hll_power_sum(arr)
    psum, zeros = estimate_kernel.power_sum(jnp.asarray(arr), block=16)
    assert zeros[0] == v_ref
    np.testing.assert_allclose(float(psum[0]), s_ref, rtol=1e-12)


def test_power_sum_block_invariance():
    rng = np.random.default_rng(13)
    regs = rng.integers(0, 49, size=1 << 14).astype(np.int32)
    vals = []
    for block in (256, 1024, 4096, 1 << 14):
        psum, zeros = estimate_kernel.power_sum(jnp.asarray(regs), block=block)
        vals.append((float(psum[0]), int(zeros[0])))
    assert all(v[1] == vals[0][1] for v in vals)
    for v in vals[1:]:
        np.testing.assert_allclose(v[0], vals[0][0], rtol=1e-12)
