"""AOT export sanity: every manifest entry lowers, is written, and the
HLO text has the entry computation the Rust loader expects."""

import os

from compile import aot


def test_variant_lists_cover_paper_config():
    assert (16, 64, 8192) in aot.AGGREGATE_VARIANTS
    assert (16, 64) in aot.ESTIMATE_VARIANTS
    assert 16 in aot.MERGE_VARIANTS


def test_build_entries_lower_and_convert(tmp_path):
    """Lower one of each kind and round it through to_hlo_text."""
    seen_kinds = set()
    for name, lowered, meta in aot.build_entries():
        if meta["kind"] in seen_kinds:
            continue
        seen_kinds.add(meta["kind"])
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
    assert seen_kinds == {"aggregate", "estimate", "merge",
                          "aggregate_estimate"}


def test_artifacts_dir_if_present_is_consistent():
    """If `make artifacts` has run, the manifest and files must agree."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest = os.path.join(art, "manifest.tsv")
    if not os.path.exists(manifest):
        return  # artifacts not built in this checkout; nothing to check
    with open(manifest) as f:
        header = f.readline().strip().split("\t")
        assert header[0] == "name"
        rows = [dict(zip(header, line.strip().split("\t"))) for line in f]
    assert rows, "empty manifest"
    for row in rows:
        path = os.path.join(art, row["file"])
        assert os.path.exists(path), row["file"]
        with open(path) as f:
            head = f.read(64)
        assert head.startswith("HloModule"), row["file"]
        assert int(row["m"]) == 1 << int(row["p"])
